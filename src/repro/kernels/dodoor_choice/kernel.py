"""Pallas kernels: fused Algorithm-1 two-choice selection.

TPU adaptation. The GPU/CPU-natural implementation gathers L[cand], D[cand],
C[cand] with a scatter/gather unit; the TPU has none worth feeding from
VMEM, so the gathers are recast as **one-hot matmuls** on the MXU:

    onehot[t, j] = (cand[t] == j)              (VPU compare against an iota)
    L_cand       = onehot @ L                  (MXU, [block_t,N]×[N,K])
    D_cand       = onehot @ D                  (same pass)

Two entry points share that trick:

* ``dodoor_choice_pallas`` — the two-stage form: candidates are sampled
  outside (``sample_feasible_batch``) and only score+select fuse.
* ``dodoor_fused_pallas``  — the megakernel: candidate *sampling* moves
  inside too, so the whole sample → score → select chain is one pass with
  one HBM read of the server table per tile and no [T, 2] candidate /
  duration intermediates round-tripping through HBM.
* ``dodoor_fused_masked_pallas`` — the megakernel's masked-sampling form:
  a per-task ``avail [T, N]`` 0/1 plane (the scenario engine's down-window
  mask) is streamed per tile and ANDed into the in-kernel prefilter, so
  ``use_kernel=True`` stays legal under outage/churn timelines.  Sampling
  arithmetic is otherwise identical, so draws remain bit-exact against
  ``sample_feasible_batch`` on the intersected mask.
* ``dodoor_fused_sparse_pallas`` (+ ``_sparse_masked``) — the
  sparse-candidate-gather megakernel.  The dense form streams a
  ``d [T, N]`` per-server duration plane per tile — the operand that
  breaks the 10⁴-server ceiling (it is the only [T, N] input, and the
  engine materializes it from a tiny ``[T, num_types]`` table).  The
  sparse form streams that ``d_types [T, TT]`` table instead (TT = node
  types, ~4) and carries each server's node type as one extra table
  column; after the candidate rows are gathered, the candidate's duration
  is a second (tiny) one-hot pick over the TT type columns.  Per-task
  bytes touched drop from O(N) to O(TT + N/block_t·(2K+3)) — the
  full-row read is gone.  Sampling arithmetic is untouched, so draws stay
  bit-exact against ``sample_feasible_batch``, and the gathered duration
  is the *same float* the dense kernel gathers (``d[t, j] ==
  d_types[t, node_type[j]]`` by construction), so choices/scores match
  the dense megakernel exactly.

Megakernel VMEM layout
----------------------
The per-tile VMEM working set is one packed server table plus the tile's
task rows:

    tbl[N, 2K+2] = [ L (K cols) | D | 1/ΣC² | C (K cols) ]

Columns 0..K-1 feed the RL numerator (one-hot matmul), column K the
duration term, column K+1 the precomputed reciprocal capacity norm
(Eq. 1's denominator), and the trailing K *prefilter columns* the
feasibility mask (Algorithm 1 line 2: ``r ≤ C`` in every dimension).
An 8192-node fleet at K=2 is ~192 KB — well under the ~16 MB/core VMEM
budget — and the table block is pinned to grid index 0, so every tile
reads it from HBM once.  Streamed per tile: ``key[block_t, 2]`` (uint32),
``r[block_t, K]``, ``d[block_t, N]`` (per-server estimated durations).

Megakernel PRNG scheme
----------------------
Candidate draws must be *draw-for-draw identical* to the two-stage path's
``jax.random.uniform(k_cand, (2,))``, so the kernel re-implements JAX's
threefry2x32 generator inline (20 rounds, rotation schedule
(13,15,26,6)/(17,29,16,24), key-schedule constant 0x1BD11BDA):

    bits0, bits1 = threefry2x32(key_lo, key_hi, counts=(0, 1))
    u            = bitcast(bits >> 9 | 0x3F800000, f32) - 1.0

exactly the mantissa-fill JAX uses for float32 uniforms.  The two uniforms
then drive the same inverse-CDF pick as ``sample_feasible``: inclusive
prefix-sum of the feasibility mask, rank ``min(int(u·k), k-1)+1``, index =
#servers whose prefix count is below the rank (with the uniform-over-all
fallback when no server is feasible).  ``tests/test_kernels.py`` /
``tests/test_engine_batched.py`` pin this bit-for-bit against
``sample_feasible_batch``.

Grid: 1-D over decision-batch tiles of ``block_t``. The server table is
broadcast to every grid step (index_map pins it to block 0).

``interpret=None`` auto-detects the backend: compiled on TPU, interpreter
mode elsewhere (the CPU test/CI path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-9

# threefry2x32 rotation schedule (Salmon et al.; matches jax._src.prng).
_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))
_PARITY = 0x1BD11BDA


def _resolve_interpret(interpret):
    """``None`` → interpreter mode unless running on a real TPU backend."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def _threefry2x32(k0, k1, x0, x1):
    """20-round threefry2x32, vectorized over uint32 arrays — bit-identical
    to JAX's generator (verified against ``jax.random.uniform``/``split``)."""
    ks = (k0, k1, k0 ^ k1 ^ jnp.uint32(_PARITY))
    x = [x0 + ks[0], x1 + ks[1]]
    for i in range(5):
        for r in _ROTATIONS[i % 2]:
            x[0] = x[0] + x[1]
            x[1] = (x[1] << r) | (x[1] >> (32 - r))
            x[1] = x[0] ^ x[1]
        x[0] = x[0] + ks[(i + 1) % 3]
        x[1] = x[1] + ks[(i + 2) % 3] + jnp.uint32(i + 1)
    return x[0], x[1]


def _unit_float(bits):
    """uint32 bits → float32 in [0, 1) via JAX's mantissa fill."""
    fb = (bits >> jnp.uint32(9)) | jnp.uint32(0x3F800000)
    return jax.lax.bitcast_convert_type(fb, jnp.float32) - 1.0


def _pair_scores(alpha, k, r, row_a, row_b, d_a, d_b):
    """LOADSCORE for gathered candidate rows (shared by both kernels).

    ``row_*[:, :k]`` = L, ``[:, k]`` = D, ``[:, k+1]`` = 1/ΣC².
    """
    rl_a = jnp.sum(r * row_a[:, :k], axis=-1) * row_a[:, k + 1]
    rl_b = jnp.sum(r * row_b[:, :k], axis=-1) * row_b[:, k + 1]
    D_a = row_a[:, k] + d_a
    D_b = row_b[:, k] + d_b
    rl_sum = rl_a + rl_b
    d_sum = D_a + D_b
    rl_fa = jnp.where(rl_sum > _EPS, rl_a / (rl_sum + _EPS), 0.5)
    rl_fb = jnp.where(rl_sum > _EPS, rl_b / (rl_sum + _EPS), 0.5)
    d_fa = jnp.where(d_sum > _EPS, D_a / (d_sum + _EPS), 0.5)
    d_fb = jnp.where(d_sum > _EPS, D_b / (d_sum + _EPS), 0.5)
    score_a = rl_fa * (1.0 - alpha) + d_fa * alpha
    score_b = rl_fb * (1.0 - alpha) + d_fb * alpha
    return score_a, score_b


def _kernel(alpha, r_ref, cand_ref, d_ref, tbl_ref, out_choice_ref,
            out_scores_ref):
    # r_ref:    [block_t, K]   task demands
    # cand_ref: [block_t, 2]   candidate ids (int32)
    # d_ref:    [block_t, 2]   per-candidate task durations
    # tbl_ref:  [N, K+2]       server table: [L (K) | D | 1/ΣC²]
    # outputs:  [block_t] int32, [block_t, 2] f32
    tbl = tbl_ref[...]
    n = tbl.shape[0]
    k = r_ref.shape[1]
    cand = cand_ref[...]                                   # [bt, 2]
    ids = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)   # [1, N]

    def gather(which):
        onehot = (cand[:, which][:, None] == ids).astype(jnp.float32)
        return jnp.dot(onehot, tbl, preferred_element_type=jnp.float32)

    row_a = gather(0)                                      # [bt, K+2]
    row_b = gather(1)
    r = r_ref[...]
    score_a, score_b = _pair_scores(alpha, k, r, row_a, row_b,
                                    d_ref[:, 0], d_ref[:, 1])

    out_scores_ref[:, 0] = score_a
    out_scores_ref[:, 1] = score_b
    out_choice_ref[...] = jnp.where(score_a > score_b, cand[:, 1],
                                    cand[:, 0]).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("alpha", "block_t", "interpret"))
def dodoor_choice_pallas(r, cand, d_cand, tbl, *, alpha: float,
                         block_t: int = 256, interpret: bool | None = None):
    """r [T,K], cand [T,2] int32, d_cand [T,2], tbl [N, K+2] → (choice [T],
    scores [T,2]). T must be a multiple of block_t (ops.py pads)."""
    T, K = r.shape
    N = tbl.shape[0]
    grid = (T // block_t,)
    kern = functools.partial(_kernel, alpha)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, K), lambda i: (i, 0)),
            pl.BlockSpec((block_t, 2), lambda i: (i, 0)),
            pl.BlockSpec((block_t, 2), lambda i: (i, 0)),
            pl.BlockSpec((N, K + 2), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_t,), lambda i: (i,)),
            pl.BlockSpec((block_t, 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((T, 2), jnp.float32),
        ],
        interpret=_resolve_interpret(interpret),
    )(r, cand, d_cand, tbl)


def _fused_kernel(alpha, k, masked, *refs):
    # key_ref:  [block_t, 2]   per-task uint32 PRNG key (k_cand)
    # r_ref:    [block_t, K]   task demands
    # d_ref:    [block_t, N]   per-server estimated durations
    # avail_ref:[block_t, N]   (masked form only) 0/1 availability plane —
    #                          per-task down-window mask from the scenario
    #                          engine's Dynamics timelines
    # tbl_ref:  [N, 2K+2]      server table: [L | D | 1/ΣC² | C]
    # outputs:  choice [bt] i32, cand [bt, 2] i32, scores [bt, 2] f32
    if masked:
        (key_ref, r_ref, d_ref, avail_ref, tbl_ref, out_choice_ref,
         out_cand_ref, out_scores_ref) = refs
    else:
        (key_ref, r_ref, d_ref, tbl_ref, out_choice_ref, out_cand_ref,
         out_scores_ref) = refs
        avail_ref = None
    tbl = tbl_ref[...]
    n = tbl.shape[0]
    r = r_ref[...]
    bt = r.shape[0]

    # --- prefilter (Algorithm 1 line 2) from the table's capacity columns,
    #     intersected with the per-task availability plane in the masked
    #     form (down windows: outages ∪ joins ∪ leaves)
    caps = tbl[:, k + 2:]                                  # [N, K]
    mask = jnp.all(r[:, None, :] <= caps[None, :, :], axis=-1)   # [bt, N]
    if avail_ref is not None:
        mask = mask & (avail_ref[...] > 0.0)
    cnt = jnp.cumsum(mask.astype(jnp.int32), axis=1)       # inclusive
    total = cnt[:, -1]                                     # [bt]
    any_ok = total > 0
    pos = jax.lax.broadcasted_iota(jnp.int32, (bt, n), 1)
    # No-feasible fallback: uniform over all servers (submission is never
    # rejected) — identical to sample_feasible's eff_cnt/kk substitution.
    eff_cnt = jnp.where(any_ok[:, None], cnt, pos + 1)
    kk = jnp.where(any_ok, total, n)                       # [bt]

    # --- per-task PRNG: uniform(k_cand, (2,)) via inline threefry
    y0, y1 = _threefry2x32(key_ref[:, 0], key_ref[:, 1],
                           jnp.zeros((bt,), jnp.uint32),
                           jnp.ones((bt,), jnp.uint32))
    u0 = _unit_float(y0)
    u1 = _unit_float(y1)

    # --- inverse-CDF prefix-sum pick (two independent RandomInt draws)
    kk_f = kk.astype(jnp.float32)
    km1 = kk - 1
    tgt0 = jnp.minimum((u0 * kk_f).astype(jnp.int32), km1) + 1
    tgt1 = jnp.minimum((u1 * kk_f).astype(jnp.int32), km1) + 1
    cand0 = jnp.sum((eff_cnt < tgt0[:, None]).astype(jnp.int32), axis=1)
    cand1 = jnp.sum((eff_cnt < tgt1[:, None]).astype(jnp.int32), axis=1)

    # --- gather candidate rows + per-candidate durations, score, select
    ids = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    d = d_ref[...]

    def gather(c):
        onehot = (c[:, None] == ids).astype(jnp.float32)
        row = jnp.dot(onehot, tbl, preferred_element_type=jnp.float32)
        d_c = jnp.sum(onehot * d, axis=-1)
        return row, d_c

    row_a, d_a = gather(cand0)
    row_b, d_b = gather(cand1)
    score_a, score_b = _pair_scores(alpha, k, r, row_a, row_b, d_a, d_b)

    out_cand_ref[:, 0] = cand0.astype(jnp.int32)
    out_cand_ref[:, 1] = cand1.astype(jnp.int32)
    out_scores_ref[:, 0] = score_a
    out_scores_ref[:, 1] = score_b
    out_choice_ref[...] = jnp.where(score_a > score_b, cand1,
                                    cand0).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("alpha", "block_t", "interpret"))
def dodoor_fused_pallas(keys, r, d, tbl, *, alpha: float,
                        block_t: int = 256, interpret: bool | None = None):
    """keys [T,2] uint32, r [T,K], d [T,N], tbl [N, 2K+2] → (choice [T],
    cand [T,2], scores [T,2]). T must be a multiple of block_t (ops pads)."""
    T, K = r.shape
    N = tbl.shape[0]
    grid = (T // block_t,)
    kern = functools.partial(_fused_kernel, alpha, K, False)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, 2), lambda i: (i, 0)),
            pl.BlockSpec((block_t, K), lambda i: (i, 0)),
            pl.BlockSpec((block_t, N), lambda i: (i, 0)),
            pl.BlockSpec((N, 2 * K + 2), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_t,), lambda i: (i,)),
            pl.BlockSpec((block_t, 2), lambda i: (i, 0)),
            pl.BlockSpec((block_t, 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((T, 2), jnp.int32),
            jax.ShapeDtypeStruct((T, 2), jnp.float32),
        ],
        interpret=_resolve_interpret(interpret),
    )(keys, r, d, tbl)


@functools.partial(jax.jit,
                   static_argnames=("alpha", "block_t", "interpret"))
def dodoor_fused_masked_pallas(keys, r, d, avail, tbl, *, alpha: float,
                               block_t: int = 256,
                               interpret: bool | None = None):
    """The masked-sampling megakernel: like :func:`dodoor_fused_pallas`
    with an extra ``avail [T, N]`` 0/1 float32 plane ANDed into the
    in-kernel prefilter, so the scenario engine's per-server down windows
    (outages, churn) ride the fused path.  The threefry draws and the
    inverse-CDF pick are untouched — draws stay bit-identical to
    ``sample_feasible_batch(keys, capacity_mask & avail, 2)``."""
    T, K = r.shape
    N = tbl.shape[0]
    grid = (T // block_t,)
    kern = functools.partial(_fused_kernel, alpha, K, True)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, 2), lambda i: (i, 0)),
            pl.BlockSpec((block_t, K), lambda i: (i, 0)),
            pl.BlockSpec((block_t, N), lambda i: (i, 0)),
            pl.BlockSpec((block_t, N), lambda i: (i, 0)),
            pl.BlockSpec((N, 2 * K + 2), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_t,), lambda i: (i,)),
            pl.BlockSpec((block_t, 2), lambda i: (i, 0)),
            pl.BlockSpec((block_t, 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((T, 2), jnp.int32),
            jax.ShapeDtypeStruct((T, 2), jnp.float32),
        ],
        interpret=_resolve_interpret(interpret),
    )(keys, r, d, avail, tbl)


def _fused_sparse_kernel(alpha, k, masked, gamma_bw, locality, *refs):
    # key_ref:  [block_t, 2]   per-task uint32 PRNG key (k_cand)
    # r_ref:    [block_t, K]   task demands
    # dt_ref:   [block_t, TT]  per-*type* estimated durations (TT = node
    #                          types) — replaces the dense [block_t, N]
    #                          per-server plane
    # avail_ref:[block_t, N]   (masked form only) 0/1 availability plane
    # psrv_ref: [block_t, P]   (locality form only) parent servers (i32,
    #                          -1 where absent)
    # pbytes_ref:[block_t, P]  (locality form only) parent output MB (0
    #                          where absent — an absent parent is inert)
    # tbl_ref:  [N, 2K+3]      server table: [L | D | 1/ΣC² | C | node_type]
    # outputs:  choice [bt] i32, cand [bt, 2] i32, scores [bt, 2] f32
    refs = list(refs)
    key_ref, r_ref, dt_ref = refs[:3]
    pos = 3
    avail_ref = psrv_ref = pbytes_ref = None
    if masked:
        avail_ref = refs[pos]
        pos += 1
    if locality:
        psrv_ref, pbytes_ref = refs[pos], refs[pos + 1]
        pos += 2
    tbl_ref, out_choice_ref, out_cand_ref, out_scores_ref = refs[pos:]
    tbl = tbl_ref[...]
    n = tbl.shape[0]
    r = r_ref[...]
    bt = r.shape[0]

    # --- prefilter + draws: identical arithmetic to _fused_kernel (the
    #     draw-for-draw contract with sample_feasible) — only the duration
    #     gather below differs.
    caps = tbl[:, k + 2:2 * k + 2]                         # [N, K]
    mask = jnp.all(r[:, None, :] <= caps[None, :, :], axis=-1)   # [bt, N]
    if avail_ref is not None:
        mask = mask & (avail_ref[...] > 0.0)
    cnt = jnp.cumsum(mask.astype(jnp.int32), axis=1)       # inclusive
    total = cnt[:, -1]                                     # [bt]
    any_ok = total > 0
    pos = jax.lax.broadcasted_iota(jnp.int32, (bt, n), 1)
    eff_cnt = jnp.where(any_ok[:, None], cnt, pos + 1)
    kk = jnp.where(any_ok, total, n)                       # [bt]

    y0, y1 = _threefry2x32(key_ref[:, 0], key_ref[:, 1],
                           jnp.zeros((bt,), jnp.uint32),
                           jnp.ones((bt,), jnp.uint32))
    u0 = _unit_float(y0)
    u1 = _unit_float(y1)

    kk_f = kk.astype(jnp.float32)
    km1 = kk - 1
    tgt0 = jnp.minimum((u0 * kk_f).astype(jnp.int32), km1) + 1
    tgt1 = jnp.minimum((u1 * kk_f).astype(jnp.int32), km1) + 1
    cand0 = jnp.sum((eff_cnt < tgt0[:, None]).astype(jnp.int32), axis=1)
    cand1 = jnp.sum((eff_cnt < tgt1[:, None]).astype(jnp.int32), axis=1)

    # --- sparse gather: candidate rows from the table (one-hot matmul as
    #     before), then the candidate's *node type* rides out as the last
    #     table column — node types are small ints, exactly representable
    #     in f32 and exactly recovered by the single-nonzero one-hot sum —
    #     and a second, tiny one-hot over the TT type columns picks the
    #     duration.  No [bt, N] duration operand exists anywhere.
    ids = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    dt = dt_ref[...]                                       # [bt, TT]
    tt_n = dt.shape[1]
    tio = jax.lax.broadcasted_iota(jnp.float32, (1, tt_n), 1)

    def gather(c):
        onehot = (c[:, None] == ids).astype(jnp.float32)
        row = jnp.dot(onehot, tbl, preferred_element_type=jnp.float32)
        nt_c = row[:, 2 * k + 2]                           # [bt] exact
        d_c = jnp.sum((nt_c[:, None] == tio).astype(jnp.float32) * dt,
                      axis=-1)
        return row, d_c

    row_a, d_a = gather(cand0)
    row_b, d_b = gather(cand1)
    score_a, score_b = _pair_scores(alpha, k, r, row_a, row_b, d_a, d_b)

    if locality:
        # Data-locality penalty (Algorithm 1 + LocalityModel): each
        # candidate is charged gamma/bandwidth per MB of parent output it
        # would have to pull remotely.  Same reduction order as the
        # two-stage path; gamma_bw = 0 adds +0.0 and reproduces the
        # locality-free scores bit-exactly.
        psrv = psrv_ref[...]                               # [bt, P] i32
        pb = pbytes_ref[...]                               # [bt, P] f32
        rem_a = jnp.sum(
            pb * (psrv != cand0[:, None]).astype(jnp.float32), axis=-1)
        rem_b = jnp.sum(
            pb * (psrv != cand1[:, None]).astype(jnp.float32), axis=-1)
        score_a = score_a + gamma_bw * rem_a
        score_b = score_b + gamma_bw * rem_b

    out_cand_ref[:, 0] = cand0.astype(jnp.int32)
    out_cand_ref[:, 1] = cand1.astype(jnp.int32)
    out_scores_ref[:, 0] = score_a
    out_scores_ref[:, 1] = score_b
    out_choice_ref[...] = jnp.where(score_a > score_b, cand1,
                                    cand0).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("alpha", "gamma_bw", "block_t",
                                    "interpret"))
def dodoor_fused_sparse_pallas(keys, r, d_types, tbl, psrv=None,
                               pbytes=None, *, alpha: float,
                               gamma_bw: float = 0.0, block_t: int = 256,
                               interpret: bool | None = None):
    """keys [T,2] uint32, r [T,K], d_types [T,TT], tbl [N, 2K+3] →
    (choice [T], cand [T,2], scores [T,2]).  T must be a multiple of
    block_t (ops.py pads).

    ``psrv [T, P]`` (int32 parent servers, −1 padded) and ``pbytes
    [T, P]`` (parent output MB, 0 padded) stream the locality gather:
    each candidate's score is charged ``gamma_bw`` per MB of parent
    output held on a different server.  ``None`` (the default) keeps the
    locality-free program; ``gamma_bw = 0`` with planes present is
    bit-identical to it."""
    T, K = r.shape
    N = tbl.shape[0]
    TT = d_types.shape[1]
    grid = (T // block_t,)
    locality = psrv is not None
    kern = functools.partial(_fused_sparse_kernel, alpha, K, False,
                             gamma_bw, locality)
    in_specs = [
        pl.BlockSpec((block_t, 2), lambda i: (i, 0)),
        pl.BlockSpec((block_t, K), lambda i: (i, 0)),
        pl.BlockSpec((block_t, TT), lambda i: (i, 0)),
    ]
    operands = [keys, r, d_types]
    if locality:
        P = psrv.shape[1]
        in_specs += [pl.BlockSpec((block_t, P), lambda i: (i, 0)),
                     pl.BlockSpec((block_t, P), lambda i: (i, 0))]
        operands += [psrv, pbytes]
    in_specs.append(pl.BlockSpec((N, 2 * K + 3), lambda i: (0, 0)))
    operands.append(tbl)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_t,), lambda i: (i,)),
            pl.BlockSpec((block_t, 2), lambda i: (i, 0)),
            pl.BlockSpec((block_t, 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((T, 2), jnp.int32),
            jax.ShapeDtypeStruct((T, 2), jnp.float32),
        ],
        interpret=_resolve_interpret(interpret),
    )(*operands)


@functools.partial(jax.jit,
                   static_argnames=("alpha", "gamma_bw", "block_t",
                                    "interpret"))
def dodoor_fused_sparse_masked_pallas(keys, r, d_types, avail, tbl,
                                      psrv=None, pbytes=None, *,
                                      alpha: float, gamma_bw: float = 0.0,
                                      block_t: int = 256,
                                      interpret: bool | None = None):
    """Masked-sampling form of :func:`dodoor_fused_sparse_pallas`: the
    ``avail [T, N]`` 0/1 plane is ANDed into the in-kernel prefilter
    exactly as in :func:`dodoor_fused_masked_pallas` — draws stay
    bit-identical to ``sample_feasible_batch`` on the intersected mask.
    Locality planes (``psrv``/``pbytes``/``gamma_bw``) compose as in the
    unmasked form."""
    T, K = r.shape
    N = tbl.shape[0]
    TT = d_types.shape[1]
    grid = (T // block_t,)
    locality = psrv is not None
    kern = functools.partial(_fused_sparse_kernel, alpha, K, True,
                             gamma_bw, locality)
    in_specs = [
        pl.BlockSpec((block_t, 2), lambda i: (i, 0)),
        pl.BlockSpec((block_t, K), lambda i: (i, 0)),
        pl.BlockSpec((block_t, TT), lambda i: (i, 0)),
        pl.BlockSpec((block_t, N), lambda i: (i, 0)),
    ]
    operands = [keys, r, d_types, avail]
    if locality:
        P = psrv.shape[1]
        in_specs += [pl.BlockSpec((block_t, P), lambda i: (i, 0)),
                     pl.BlockSpec((block_t, P), lambda i: (i, 0))]
        operands += [psrv, pbytes]
    in_specs.append(pl.BlockSpec((N, 2 * K + 3), lambda i: (0, 0)))
    operands.append(tbl)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_t,), lambda i: (i,)),
            pl.BlockSpec((block_t, 2), lambda i: (i, 0)),
            pl.BlockSpec((block_t, 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((T, 2), jnp.int32),
            jax.ShapeDtypeStruct((T, 2), jnp.float32),
        ],
        interpret=_resolve_interpret(interpret),
    )(*operands)
