"""Pure-jnp oracle for Mamba-2's SSD layer: the literal linear recurrence.

State h [S, P] per (batch, head); per step t:

    h_t = exp(A·dt_t) · h_{t-1} + dt_t · B_t xᵀ_t        (outer product)
    y_t = C_t · h_t

A is a per-head negative scalar; B, C are shared across head groups (G
groups, like GQA for state space models). This O(L·S·P) scan is the ground
truth the chunked (quadratic-within-chunk) kernel must match.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray,
            C: jnp.ndarray, h0: jnp.ndarray | None = None):
    """x [B,L,H,P]; dt [B,L,H] (>0, post-softplus); A [H] (<0);
    B, C [B,L,G,S] with H divisible by G.

    Returns (y [B,L,H,P], h_final [B,H,S,P]).
    """
    Bb, L, H, P = x.shape
    G, S = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2)         # [B,L,H,S]
    Ch = jnp.repeat(C, rep, axis=2)

    def per_bh(xs, dts, Bs, Cs, a, h_init):
        # xs [L,P], dts [L], Bs/Cs [L,S], a scalar, h_init [S,P]
        def step(h, inp):
            xt, dtt, Bt, Ct = inp
            h = jnp.exp(a * dtt) * h + dtt * (Bt[:, None] * xt[None, :])
            return h, Ct @ h
        h, ys = jax.lax.scan(step, h_init, (xs, dts, Bs, Cs))
        return ys, h

    if h0 is None:
        h0 = jnp.zeros((Bb, H, S, P), x.dtype)
    f = jax.vmap(jax.vmap(per_bh, in_axes=(1, 1, 1, 1, 0, 0),
                          out_axes=(1, 0)),
                 in_axes=(0, 0, 0, 0, None, 0), out_axes=(0, 0))
    # inner vmap over heads: x [L,H,P] → axis 1; outer over batch.
    y, h = f(x, dt, Bh, Ch, A, h0)
    return y, h
