"""Pallas kernel: the Mamba-2 SSD intra-chunk block (state-space duality).

The SSD algorithm (arXiv:2405.21060) splits the linear recurrence into
chunks of length Q. Within a chunk everything is a masked-decay matmul —
exactly what the MXU wants — and only an [S,P] state crosses chunk
boundaries. This kernel computes, per (batch·head, chunk) grid cell:

    s_t        = Σ_{u≤t} A·dt_u                    (cumulative log-decay)
    y_intra[t] = Σ_{u≤t} exp(s_t−s_u)·dt_u·(C_t·B_u)·x_u
    H_out      = Σ_u exp(s_Q−s_u)·dt_u·B_uᵀ x_u    ([S,P] chunk state)
    exp_s[t]   = exp(s_t)                          (for the h_in correction)

All decays are ≤ 1 because A<0 and dt>0, so no log-space tricks are needed.
The O(Q²) logits tile (C Bᵀ ⊙ decay-mask) lives in VMEM; x, B, C tiles are
read once from HBM. The inter-chunk scan (a cheap [S,P] recurrence) and the
h_in correction stay in the ops.py wrapper — they are O(L·S·P) and XLA
handles them well; the kernel owns the O(L·Q·(S+P)) hot part.

Grid: (B·H, num_chunks); B/C tiles are indexed per-head-group through the
BlockSpec index map, so grouped state matrices are never duplicated in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, delta_ref, dtv_ref, b_ref, c_ref, y_ref, h_ref, es_ref):
    # x [1,1,Q,P]; delta/dtv [1,1,Q]; b/c [1,1,1,Q,S] → strip leading axes.
    x = x_ref[0, 0]                     # [Q, P]
    delta = delta_ref[0, 0]             # [Q]  (= A·dt, negative)
    dtv = dtv_ref[0, 0]                 # [Q]
    Bc = b_ref[0, 0, 0]                 # [Q, S]
    Cc = c_ref[0, 0, 0]                 # [Q, S]
    Q = x.shape[0]

    s = jnp.cumsum(delta)               # [Q] inclusive
    # Lower-triangular (inclusive) decay mask M[t,u] = exp(s_t - s_u), u ≤ t.
    # diff ≤ 0 on the valid triangle; clamp so the masked region never
    # overflows exp (keeps the custom-vjp path NaN-free).
    diff = jnp.minimum(s[:, None] - s[None, :], 0.0)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    u_idx = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    M = jnp.where(u_idx <= t_idx, jnp.exp(diff), 0.0)

    CB = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    G = CB * M * dtv[None, :]
    y = jax.lax.dot_general(G, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [Q, P]

    w = jnp.exp(s[Q - 1] - s) * dtv                                # [Q]
    H = jax.lax.dot_general(Bc * w[:, None], x, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [S, P]

    y_ref[0, 0] = y.astype(y_ref.dtype)
    h_ref[0, 0] = H.astype(h_ref.dtype)
    es_ref[0, 0] = jnp.exp(s).astype(es_ref.dtype)


@functools.partial(jax.jit, static_argnames=("heads_per_group", "interpret"))
def ssd_chunk_pallas(x, delta, dtv, Bm, Cm, *, heads_per_group: int,
                     interpret: bool = True):
    """x [BH, NC, Q, P]; delta/dtv [BH, NC, Q]; Bm/Cm [B, G, NC, Q, S].

    BH = B·H with heads fastest-varying (bh = b·H + h); the index map sends
    grid cell (bh, c) to (b, h // heads_per_group, c) in Bm/Cm.

    Returns (y_intra [BH,NC,Q,P], H_out [BH,NC,S,P], exp_s [BH,NC,Q]).
    """
    BH, NC, Q, P = x.shape
    Bb, G, _, _, S = Bm.shape
    H = BH // Bb
    hpg = heads_per_group

    def bc_map(bh, c):
        return (bh // H, (bh % H) // hpg, c, 0, 0)

    return pl.pallas_call(
        _kernel,
        grid=(BH, NC),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda bh, c: (bh, c, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, 1, 1, Q, S), bc_map),
            pl.BlockSpec((1, 1, 1, Q, S), bc_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda bh, c: (bh, c, 0, 0)),
            pl.BlockSpec((1, 1, S, P), lambda bh, c: (bh, c, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda bh, c: (bh, c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, NC, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, NC, S, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, NC, Q), jnp.float32),
        ],
        interpret=interpret,
    )(x, delta, dtv, Bm, Cm)
