"""Public SSD wrapper: chunking, the inter-chunk state scan, h_in correction."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import ssd_chunk_pallas


def ssd(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray,
        C: jnp.ndarray, h0: jnp.ndarray | None = None, *,
        chunk: int = 64, interpret: bool = True):
    """Chunked SSD with the oracle's signature (see ref.py): x [B,L,H,P],
    dt [B,L,H], A [H], B/C [B,L,G,S]. L must be a multiple of ``chunk``
    (the model layer pads sequences). Returns (y [B,L,H,P], h [B,H,S,P])."""
    Bb, L, H, P = x.shape
    G, S = B.shape[2], B.shape[3]
    NC = L // chunk
    hpg = H // G

    # Layouts for the kernel: heads into the batch dim, chunked time.
    xk = x.transpose(0, 2, 1, 3).reshape(Bb * H, NC, chunk, P)
    dtk = dt.transpose(0, 2, 1).reshape(Bb * H, NC, chunk)
    delta = dtk * jnp.tile(A, Bb)[:, None, None]     # A·dt per (b·H+h)
    Bk = B.transpose(0, 2, 1, 3).reshape(Bb, G, NC, chunk, S)
    Ck = C.transpose(0, 2, 1, 3).reshape(Bb, G, NC, chunk, S)

    y_intra, H_out, exp_s = ssd_chunk_pallas(
        xk.astype(jnp.float32), delta.astype(jnp.float32),
        dtk.astype(jnp.float32), Bk.astype(jnp.float32),
        Ck.astype(jnp.float32), heads_per_group=hpg, interpret=interpret)

    # Inter-chunk state recurrence: h_c = decay_c · h_{c-1} + H_out_c, with
    # decay_c = exp(Σ chunk deltas) = exp_s[..., -1].
    if h0 is None:
        h0 = jnp.zeros((Bb * H, S, P), jnp.float32)
    else:
        h0 = h0.reshape(Bb * H, S, P).astype(jnp.float32)

    def scan_fn(h, inp):
        Hc, decay = inp                      # [BH,S,P], [BH]
        h_next = decay[:, None, None] * h + Hc
        return h_next, h                     # emit the *incoming* state

    decays = exp_s[:, :, -1]                 # [BH, NC]
    h_final, h_in = jax.lax.scan(
        scan_fn, h0, (H_out.transpose(1, 0, 2, 3), decays.T))
    h_in = h_in.transpose(1, 0, 2, 3)        # [BH, NC, S, P]

    # h_in correction: y_state[t] = exp(s_t) · C_t · h_in(chunk).
    # C is per-group: fold heads as [B, G, hpg, ...] to avoid repeating.
    Ck_g = Ck.reshape(Bb, G, NC, chunk, S)
    h_in_g = h_in.reshape(Bb, G, hpg, NC, S, P)
    y_state = jnp.einsum("bgnqs,bghnsp->bghnqp", Ck_g, h_in_g)
    y_state = y_state.reshape(Bb * H, NC, chunk, P) * exp_s[..., None]

    y = (y_intra + y_state).reshape(Bb, H, L, P).transpose(0, 2, 1, 3)
    return y.astype(x.dtype), h_final.reshape(Bb, H, S, P)


def ssd_decode_step(x_t, dt_t, A, B_t, C_t, h):
    """Single-token SSD update (serving): x_t [B,H,P], dt_t [B,H], A [H],
    B_t/C_t [B,G,S], h [B,H,S,P] → (y_t [B,H,P], h')."""
    Bb, H, P = x_t.shape
    G, S = B_t.shape[1], B_t.shape[2]
    rep = H // G
    Bh = jnp.repeat(B_t, rep, axis=1)        # [B,H,S]
    Ch = jnp.repeat(C_t, rep, axis=1)
    decay = jnp.exp(A[None, :] * dt_t)       # [B,H]
    h = (decay[..., None, None] * h
         + dt_t[..., None, None] * Bh[..., None] * x_t[:, :, None, :])
    y = jnp.einsum("bhs,bhsp->bhp", Ch, h)
    return y, h
