from .ops import ssd
from .ref import ssd_ref

__all__ = ["ssd", "ssd_ref"]
