"""repro.kernels — Pallas TPU kernels for the framework's compute hot-spots.

Each subpackage ships three layers:

* ``kernel.py`` — the ``pl.pallas_call`` body with explicit BlockSpec VMEM
  tiling (TPU is the *target*; this container validates via interpret mode);
* ``ops.py``    — the jit'd public wrapper (padding, grid math, dtypes);
* ``ref.py``    — the pure-jnp oracle every kernel is tested against.

Kernels:

* ``rl_score``       — batched Eq.-1 RL scores (tasks × servers) as an MXU
                       matmul with fused per-server capacity scaling. The
                       paper's hot path, re-thought for the systolic array.
* ``dodoor_choice``  — fused Algorithm-1 two-choice: one-hot candidate
                       gathers (MXU-friendly, no scatter/gather unit),
                       loadScore, and argmin select, one pass over VMEM.
* ``flash_attention``— blockwise-softmax attention (causal / local-window /
                       GQA) for the serving stack's long-context cells.
* ``ssd_chunk``      — Mamba-2 SSD intra-chunk quadratic block (the chunked
                       state-space-duality algorithm's MXU-heavy part).
"""
from . import dodoor_choice, flash_attention, rl_score, ssd_chunk

__all__ = ["rl_score", "dodoor_choice", "flash_attention", "ssd_chunk"]
