"""Pure-jnp oracle for the batched RL score (delegates to the paper core)."""
import jax.numpy as jnp

from ...core.rl_score import rl_score_matrix as _core


def rl_score_matrix_ref(r: jnp.ndarray, L: jnp.ndarray,
                        C: jnp.ndarray) -> jnp.ndarray:
    """score[t, j] = (r_t · L_j) / ||C_j||²  — Eq. 1 batched. [T,K]×[N,K]→[T,N]."""
    return _core(r, L, C)
