"""Public wrapper: padding, layout, and the jit boundary for rl_score."""
from __future__ import annotations

import functools

import jax.numpy as jnp

from .kernel import rl_score_pallas


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def rl_score_matrix(r: jnp.ndarray, L: jnp.ndarray, C: jnp.ndarray,
                    *, block_t: int = 128, block_n: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """Batched Eq. 1 via the Pallas kernel. r [T,K], L [N,K], C [N,K] → [T,N].

    Pads T/N up to block multiples, transposes L once (the kernel wants the
    contraction dim leading for the MXU), and slices the result back.
    """
    T, K = r.shape
    N = L.shape[0]
    inv_cap = (1.0 / jnp.sum(C.astype(jnp.float32) ** 2, axis=-1))[None, :]
    r_p = _pad_to(r.astype(jnp.float32), 0, block_t)
    L_tp = _pad_to(L.astype(jnp.float32).T, 1, block_n)
    inv_p = _pad_to(inv_cap, 1, block_n)
    out = rl_score_pallas(r_p, L_tp, inv_p, block_t=block_t, block_n=block_n,
                          interpret=interpret)
    return out[:T, :N]
