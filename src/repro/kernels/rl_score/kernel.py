"""Pallas kernel: batched Eq.-1 RL scores as a tiled MXU contraction.

TPU adaptation of the paper's hot path. The Java prototype computes one RL
score per RPC-handler thread; here a *batch* of T pending decisions is scored
against all N servers in one pass:

    score[t, j] = (r[t] · L[j]) / Σ_k C[j,k]²

which is a [T,K]×[K,N] matmul (K = resource dims, zero-padded to the 128-lane
register width) with a per-column scale. The inverse capacity norms are
precomputed once per cache refresh (they only change when the fleet changes)
and fused into the epilogue, so the kernel reads each (L, C) tile exactly
once from HBM into VMEM.

Tiling: (block_t × K) ⊗ (K × block_n) → (block_t × block_n) accumulated in
f32. block_t = block_n = 128 matches the MXU systolic dimensions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(r_ref, lt_ref, inv_ref, out_ref):
    # r_ref:   [block_t, K]       task demand tile
    # lt_ref:  [K, block_n]       server load tile (pre-transposed)
    # inv_ref: [1, block_n]       1 / ||C_j||² for the tile's servers
    # out_ref: [block_t, block_n]
    scores = jnp.dot(r_ref[...], lt_ref[...],
                     preferred_element_type=jnp.float32)
    out_ref[...] = scores * inv_ref[...]


@functools.partial(jax.jit, static_argnames=("block_t", "block_n", "interpret"))
def rl_score_pallas(r: jnp.ndarray, L_t: jnp.ndarray, inv_cap: jnp.ndarray,
                    *, block_t: int = 128, block_n: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """r [T, K], L_t [K, N] (transposed loads), inv_cap [1, N] → scores [T, N].

    T and N must already be padded to multiples of the block sizes (ops.py
    handles padding); K is kept whole per tile (K ≤ 128 always: the paper
    uses K=2, extensible to disk/GPU dims).
    """
    T, K = r.shape
    _, N = L_t.shape
    grid = (T // block_t, N // block_n)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_t, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((T, N), jnp.float32),
        interpret=interpret,
    )(r, L_t, inv_cap)
