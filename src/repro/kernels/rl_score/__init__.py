from .ops import rl_score_matrix
from .ref import rl_score_matrix_ref

__all__ = ["rl_score_matrix", "rl_score_matrix_ref"]
