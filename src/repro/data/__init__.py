from .pipeline import SyntheticLM, make_batch_iterator

__all__ = ["SyntheticLM", "make_batch_iterator"]
