"""Deterministic synthetic token pipeline.

Design goals of a production pipeline kept intact at miniature scale:

* **Step-indexed determinism** — batch(i) is a pure function of (seed, i),
  so a restarted job resumes mid-epoch with no state file and elastic
  re-sharding never re-reads a "cursor" (the fault-tolerance substrate
  depends on this);
* **Host-sharded** — each data-parallel host materializes only its slice;
* **Learnable structure** — tokens follow a stationary order-2 Markov chain
  (fixed random transition logits), so the CE loss of a training run has a
  floor below uniform entropy and "loss goes down" is a meaningful test.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_states: int = 64           # Markov states (vocab buckets)

    def _chain(self):
        rng = np.random.RandomState(self.seed)
        # Sparse-ish row-stochastic transitions over states.
        logits = rng.randn(self.n_states, self.n_states) * 2.0
        return jnp.asarray(logits, jnp.float32)

    def batch(self, step: int, *, host_index: int = 0, num_hosts: int = 1):
        """(tokens, labels) for ``step``; host gets rows
        [host_index·b_local, (host_index+1)·b_local)."""
        b_local = self.global_batch // num_hosts
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        key = jax.random.fold_in(key, host_index)
        logits = self._chain()

        def one_row(k):
            def step_fn(carry, k_t):
                state = carry
                nxt = jax.random.categorical(k_t, logits[state])
                return nxt, nxt

            ks = jax.random.split(k, self.seq_len + 1)
            s0 = jax.random.randint(ks[0], (), 0, self.n_states)
            _, states = jax.lax.scan(step_fn, s0, ks[1:])
            # Map states onto the vocab (stride so ids spread the range).
            stride = max(1, self.vocab // self.n_states)
            return (states * stride) % self.vocab

        rows = jax.vmap(one_row)(jax.random.split(key, b_local))
        tokens = rows.astype(jnp.int32)
        labels = jnp.concatenate([tokens[:, 1:],
                                  tokens[:, :1]], axis=1)
        return {"tokens": tokens, "labels": labels}


def make_batch_iterator(vocab: int, seq_len: int, global_batch: int,
                        seed: int = 0, start_step: int = 0,
                        host_index: int = 0, num_hosts: int = 1):
    """Infinite iterator of (step, batch) — resumable from ``start_step``."""
    src = SyntheticLM(vocab, seq_len, global_batch, seed)
    step = start_step
    while True:
        yield step, src.batch(step, host_index=host_index,
                              num_hosts=num_hosts)
        step += 1
