"""RecurrentGemma (Griffin-style hybrid): RG-LRU recurrent blocks + local
sliding-window attention in a repeating (R, R, A) pattern.

Each residual layer is a temporal-mixing block (RG-LRU *or* local attention)
followed by a gated MLP. The RG-LRU recurrence (arXiv:2402.19427):

    r_t = σ(W_a x_t + b_a)            recurrence gate
    i_t = σ(W_x x_t + b_x)            input gate
    a_t = exp(−c · softplus(Λ) · r_t) per-channel decay (c = 8)
    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

computed with ``lax.associative_scan`` (O(log L) depth) for train/prefill and
one multiply-add per token for decode — the constant-size state is what makes
the ``long_500k`` cells runnable for this arch.

Scan-over-layers with a heterogeneous pattern: parameters are stacked per
*pattern block* (one (R, R, A) triple), scanned over blocks; the pattern
remainder (26 = 8·3 + 2 → two extra R layers) is unrolled.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import dense_init, mlp_apply, mlp_init, rms_norm, stack_init
from .transformer import attn_decode, attn_init, attn_apply
from . import analysis

Params = Dict[str, Any]

_C = 8.0


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def rglru_init(key, width: int):
    ks = jax.random.split(key, 2)
    return {
        "w_a": dense_init(ks[0], width, width, scale=width ** -0.5),
        "b_a": jnp.zeros((width,)),
        "w_x": dense_init(ks[1], width, width, scale=width ** -0.5),
        "b_x": jnp.zeros((width,)),
        # Λ initialized so a ∈ (0.9, 0.999) at r = 1 (Griffin's range).
        "lam": jnp.linspace(0.2, 2.0, width),
    }


def _gates(p, x):
    r = jax.nn.sigmoid(x @ p["w_a"] + p["b_a"])
    i = jax.nn.sigmoid(x @ p["w_x"] + p["b_x"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r          # [B,L,W]
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x)
    return a, gated


def rglru_apply(p, x, h0=None):
    """x [B, L, W] → (y [B, L, W], h_last [B, W])."""
    a, b = _gates(p, x.astype(jnp.float32))

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    A, Bv = jax.lax.associative_scan(combine, (a, b), axis=1)
    if h0 is not None:
        Bv = Bv + A * h0[:, None]
    return Bv.astype(x.dtype), Bv[:, -1]


def rglru_step(p, x_t, h):
    """x_t [B, 1, W]; h [B, W]."""
    a, b = _gates(p, x_t.astype(jnp.float32))
    h = a[:, 0] * h + b[:, 0]
    return h.astype(x_t.dtype)[:, None], h


# ---------------------------------------------------------------------------
# recurrent block: y = W_o[ gelu(W_y x) ⊙ conv→rglru(W_x x) ]
# ---------------------------------------------------------------------------

def rec_block_init(key, cfg: ModelConfig):
    w = cfg.lru_width or cfg.d_model
    ks = jax.random.split(key, 5)
    return {
        "w_y": dense_init(ks[0], cfg.d_model, w),
        "w_in": dense_init(ks[1], cfg.d_model, w),
        "conv_w": jax.random.normal(ks[2], (cfg.conv_kernel, w)) * 0.1,
        "conv_b": jnp.zeros((w,)),
        "lru": rglru_init(ks[3], w),
        "w_out": dense_init(ks[4], w, cfg.d_model),
    }


def _causal_conv(x, w, b):
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(pad[:, i:i + x.shape[1]] * w[i] for i in range(K)) + b


def rec_block_apply(p, x):
    y = jax.nn.gelu(x @ p["w_y"])
    u = _causal_conv(x @ p["w_in"], p["conv_w"], p["conv_b"])
    u, _ = rglru_apply(p["lru"], u)
    return (y * u) @ p["w_out"]


def rec_block_decode(p, x_t, conv_state, h):
    """conv_state [B, K−1, W]; h [B, W]."""
    y = jax.nn.gelu(x_t @ p["w_y"])
    u_t = (x_t @ p["w_in"])[:, 0]                        # [B, W]
    window = jnp.concatenate([conv_state, u_t[:, None]], axis=1)
    conv_state = window[:, 1:]
    u = jnp.einsum("bkw,kw->bw", window, p["conv_w"]) + p["conv_b"]
    u, h = rglru_step(p["lru"], u[:, None], h)
    return (y * u) @ p["w_out"], conv_state, h


# ---------------------------------------------------------------------------
# hybrid stack
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig):
    """One pattern block: len(pattern) sublayers, each mixer + MLP."""
    subs = []
    ks = jax.random.split(key, len(cfg.block_pattern))
    for kind, k in zip(cfg.block_pattern, ks):
        k1, k2 = jax.random.split(k)
        mix = attn_init(k1, cfg) if kind == "attn" else rec_block_init(k1, cfg)
        subs.append({
            "ln1": jnp.ones((cfg.d_model,)),
            "ln2": jnp.ones((cfg.d_model,)),
            "mix": mix,
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act),
        })
    return tuple(subs)


def init_params(cfg: ModelConfig, key) -> Params:
    pat = len(cfg.block_pattern)
    n_blocks = cfg.n_layers // pat
    n_rem = cfg.n_layers - n_blocks * pat
    ks = jax.random.split(key, 3)
    p = {
        "embed": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model)) * 0.02,
        "blocks": stack_init(ks[1], n_blocks, lambda k: _block_init(k, cfg)),
        "ln_f": jnp.ones((cfg.d_model,)),
    }
    if n_rem:
        rem_cfg_pat = cfg.block_pattern[:n_rem]
        rk = jax.random.split(ks[2], n_rem)
        rem = []
        for kind, k in zip(rem_cfg_pat, rk):
            k1, k2 = jax.random.split(k)
            mix = (attn_init(k1, cfg) if kind == "attn"
                   else rec_block_init(k1, cfg))
            rem.append({"ln1": jnp.ones((cfg.d_model,)),
                        "ln2": jnp.ones((cfg.d_model,)),
                        "mix": mix,
                        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act)})
        p["rem"] = tuple(rem)
    return p


def _sublayer(cfg, kind, sp, x, positions):
    h = rms_norm(x, sp["ln1"], cfg.norm_eps)
    if kind == "attn":
        a, _ = attn_apply(sp["mix"], h, cfg, positions, window=cfg.window)
    else:
        a = rec_block_apply(sp["mix"], h)
    x = x + a
    x = x + mlp_apply(sp["mlp"], rms_norm(x, sp["ln2"], cfg.norm_eps),
                      cfg.act)
    return x


def forward(cfg: ModelConfig, p: Params, batch, *, remat: bool = True,
            unembed: bool = True):
    x = p["embed"][batch["tokens"]]
    B, L = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))

    def block_fn(h, bp):
        for kind, sp in zip(cfg.block_pattern, bp):
            h = _sublayer(cfg, kind, sp, h, positions)
        return h, None

    fn = jax.checkpoint(block_fn) if remat else block_fn
    x, _ = analysis.scan(fn, x, p["blocks"])
    for kind, sp in zip(cfg.block_pattern, p.get("rem", ())):
        x = _sublayer(cfg, kind, sp, x, positions)
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    return (x @ p["embed"].T if unembed else x), {}


# ---------------------------------------------------------------------------
# decode — attention layers cache only the local window (bounded memory)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    pat = cfg.block_pattern
    n_blocks = cfg.n_layers // len(pat)
    n_rem = cfg.n_layers - n_blocks * len(pat)
    w = cfg.lru_width or cfg.d_model
    win = min(cfg.window or max_len, max_len)
    per_block = {}
    for i, kind in enumerate(pat):
        if kind == "attn":
            per_block[f"k{i}"] = jnp.zeros(
                (n_blocks, batch, cfg.n_kv, win, cfg.head_dim), dtype)
            per_block[f"v{i}"] = jnp.zeros(
                (n_blocks, batch, cfg.n_kv, win, cfg.head_dim), dtype)
        else:
            per_block[f"conv{i}"] = jnp.zeros(
                (n_blocks, batch, cfg.conv_kernel - 1, w), dtype)
            per_block[f"h{i}"] = jnp.zeros((n_blocks, batch, w), jnp.float32)
    rem = {}
    for i, kind in enumerate(pat[:n_rem]):
        rem[f"conv{i}"] = jnp.zeros((batch, cfg.conv_kernel - 1, w), dtype)
        rem[f"h{i}"] = jnp.zeros((batch, w), jnp.float32)
    return {"blocks": per_block, "rem": rem, "idx": jnp.zeros((), jnp.int32)}


def decode_step(cfg: ModelConfig, p: Params, cache: Params, token):
    """Local-window attention caches are rings of length ``window``; the
    write index wraps and the decode mask follows absolute positions."""
    x = p["embed"][token]
    idx = cache["idx"]
    win = min(cfg.window or 1, 10 ** 9)
    ring = idx % win

    def block_fn(h, inp):
        bp, bc = inp
        new_c = dict(bc)
        for i, kind in enumerate(cfg.block_pattern):
            sp = bp[i]
            hn = rms_norm(h, sp["ln1"], cfg.norm_eps)
            if kind == "attn":
                a, kc, vc = attn_decode(
                    sp["mix"], hn, cfg, bc[f"k{i}"].astype(h.dtype),
                    bc[f"v{i}"].astype(h.dtype), ring, window=None)
                # ring buffer: every cached slot is within the window; the
                # decode mask over a full ring is all-valid.
                new_c[f"k{i}"] = kc.astype(bc[f"k{i}"].dtype)
                new_c[f"v{i}"] = vc.astype(bc[f"v{i}"].dtype)
            else:
                a, cs, hs = rec_block_decode(
                    sp["mix"], hn, bc[f"conv{i}"].astype(h.dtype),
                    bc[f"h{i}"])
                new_c[f"conv{i}"] = cs.astype(bc[f"conv{i}"].dtype)
                new_c[f"h{i}"] = hs
            h = h + a
            h = h + mlp_apply(sp["mlp"], rms_norm(h, sp["ln2"], cfg.norm_eps),
                              cfg.act)
        return h, new_c

    x, new_blocks = analysis.scan(block_fn, x, (p["blocks"], cache["blocks"]))
    new_rem = dict(cache["rem"])
    pat = cfg.block_pattern
    for i, sp in enumerate(p.get("rem", ())):
        kind = pat[i]
        hn = rms_norm(x, sp["ln1"], cfg.norm_eps)
        a, cs, hs = rec_block_decode(sp["mix"], hn,
                                     cache["rem"][f"conv{i}"].astype(x.dtype),
                                     cache["rem"][f"h{i}"])
        new_rem[f"conv{i}"] = cs.astype(cache["rem"][f"conv{i}"].dtype)
        new_rem[f"h{i}"] = hs
        x = x + a
        x = x + mlp_apply(sp["mlp"], rms_norm(x, sp["ln2"], cfg.norm_eps),
                          cfg.act)
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    return x @ p["embed"].T, {"blocks": new_blocks, "rem": new_rem,
                              "idx": idx + 1}
