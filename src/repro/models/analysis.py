"""Analysis-mode switch for cost-exact lowering.

XLA's ``cost_analysis()`` counts a while-loop body ONCE regardless of trip
count (verified empirically on the CPU backend), so scan-heavy models report
flops/bytes/collectives that are off by the product of trip counts. The
dry-run therefore lowers *analysis twins* of each cell — same math, inner
scans unrolled, at n_layers ∈ {1, 2} — and reconstructs exact per-step costs
as ``overhead + per_layer_delta × n_layers`` (see launch/dryrun.py).

``scan()`` is the project-wide lax.scan wrapper that obeys the flag; the
production path (flag off) lowers compact scans exactly as before.
"""
from __future__ import annotations

import contextlib

import jax

_UNROLL = False


def set_unroll(value: bool) -> None:
    global _UNROLL
    _UNROLL = bool(value)


def unrolling() -> bool:
    return _UNROLL


@contextlib.contextmanager
def unrolled():
    old = _UNROLL
    set_unroll(True)
    try:
        yield
    finally:
        set_unroll(old)


def scan(f, init, xs, **kw):
    if _UNROLL:
        kw["unroll"] = True
    return jax.lax.scan(f, init, xs, **kw)
