"""Shared model building blocks: norms, RoPE/M-RoPE, chunked attention, MLPs.

Everything is functional (params are nested dicts of arrays) so models flow
through ``jax.eval_shape`` for the allocation-free dry-run, ``lax.scan`` over
stacked layer params, and pjit sharding unchanged.

Attention is **chunked** (flash-style running softmax in plain jnp): the
[L, L] logits tensor is never materialized — at the 32k-prefill cells a dense
mask would be a ~200 GB temporary. Q-chunks are a static Python loop, each
scanning exactly the KV extent causality/windowing allows (no wasted FLOPs in
the compiled HLO); KV-chunks are an inner ``lax.scan`` with running
(max, sum, acc) state, bounding the live temporary to [B, H, q_chunk,
k_chunk]. The Pallas kernel (repro.kernels.flash_attention) is the TPU hot
path with identical semantics; this jnp version is what the dry-run lowers,
so cost/memory analysis reflects the chunked schedule.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import analysis

Params = Dict[str, Any]

_NEG = -1e30


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, scale: float | None = None,
               dtype=jnp.float32) -> jnp.ndarray:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), dtype) * scale


def stack_init(key, n: int, init_fn):
    """Stack ``n`` independently-initialized pytrees along axis 0 (for
    scan-over-layers)."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * w


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def _rotate(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    x1, x2 = x[..., ::2], x[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x [B, H, L, D]; positions [B, L] (absolute token positions)."""
    freqs = rope_freqs(x.shape[-1], theta)                       # [D/2]
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs
    return _rotate(x, jnp.cos(angles), jnp.sin(angles))


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections=(2, 3, 3)) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: rotary channels split into (temporal,
    height, width) sections, each rotated by its own position stream.
    positions3 [B, 3, L]; equal streams recover standard RoPE exactly.
    ``sections`` are relative weights over the D/2 channels (2:3:3)."""
    half = x.shape[-1] // 2
    total = sum(sections)
    bounds, acc = [], 0
    for s in sections[:-1]:
        acc += round(half * s / total)
        bounds.append(acc)
    chan = jnp.arange(half)
    sec = jnp.zeros((half,), jnp.int32)
    for b in bounds:
        sec = sec + (chan >= b).astype(jnp.int32)                # [half]∈{0,1,2}
    freqs = rope_freqs(x.shape[-1], theta)                       # [half]
    pos_per_chan = jnp.transpose(positions3, (0, 2, 1)).astype(
        jnp.float32)[..., sec]                                   # [B, L, half]
    angles = pos_per_chan[:, None] * freqs                       # [B,1,L,half]
    return _rotate(x, jnp.cos(angles), jnp.sin(angles))


def text_positions3(positions: jnp.ndarray) -> jnp.ndarray:
    """[B, L] → [B, 3, L]: the degenerate M-RoPE streams for pure text."""
    return jnp.broadcast_to(positions[:, None],
                            (positions.shape[0], 3, positions.shape[1]))


# ---------------------------------------------------------------------------
# chunked (flash-style) attention — jnp, compiled-memory bounded
# ---------------------------------------------------------------------------

def _attn_block(q, k, v, m, l, acc, q0, k0, *, causal: bool,
                window: Optional[int], kv_offset: int, kv_len: int,
                scale: float):
    """One (q-chunk × kv-chunk) update of the running softmax.

    q [B,H,Qc,D]; k, v [B,H,Kc,D]; (m, l) [B,H,Qc,1]; acc [B,H,Qc,D].
    ``q0``/``k0``: absolute chunk-start positions (k0 may be traced);
    ``kv_offset`` = Lk − Lq aligns query positions; rows ≥ ``kv_len`` are
    padding and always masked.
    """
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    Qc, Kc = q.shape[2], k.shape[2]
    q_pos = q0 + kv_offset + jax.lax.broadcasted_iota(jnp.int32, (Qc, Kc), 0)
    k_pos = k0 + jax.lax.broadcasted_iota(jnp.int32, (Qc, Kc), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None] if mask.ndim == 2 else mask,
                       logits, _NEG)
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m, m_cur)
    p = jnp.exp(logits - m_new)
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc * corr + jnp.einsum("bhqk,bhkd->bhqd", p, v,
                                      preferred_element_type=jnp.float32)
    return m_new, l_new, acc_new


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, window: Optional[int] = None,
              q_chunk: int = 1024, k_chunk: int = 1024) -> jnp.ndarray:
    """Chunked attention. q [B,H,Lq,D]; k, v [B,Hkv,Lk,D] (H divisible by
    Hkv; queries are right-aligned against keys). Returns [B,H,Lq,D]."""
    B, H, Lq, D = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    rep = H // Hkv
    scale = D ** -0.5
    kv_offset = Lk - Lq

    # GQA: materialize grouped K/V views once (XLA keeps these as broadcasts
    # under sharding; HBM reads stay at Hkv granularity on TPU).
    kf = jnp.broadcast_to(k[:, :, None], (B, Hkv, rep, Lk, D)
                          ).reshape(B, H, Lk, D)
    vf = jnp.broadcast_to(v[:, :, None], (B, Hkv, rep, Lk, D)
                          ).reshape(B, H, Lk, D)

    q_chunk = min(q_chunk, Lq)
    k_chunk = min(k_chunk, Lk)
    outs = []
    for q0 in range(0, Lq, q_chunk):            # static loop: exact KV extent
        qc = min(q_chunk, Lq - q0)
        q_blk = q[:, :, q0:q0 + qc]
        hi = Lk if not causal else min(Lk, q0 + qc + kv_offset)
        lo = 0 if window is None else max(0, q0 + kv_offset - window + 1)
        lo = (lo // k_chunk) * k_chunk
        n_k = max(1, -(-(hi - lo) // k_chunk))

        pad_hi = lo + n_k * k_chunk
        if pad_hi > Lk:
            kf_p = jnp.pad(kf, ((0, 0), (0, 0), (0, pad_hi - Lk), (0, 0)))
            vf_p = jnp.pad(vf, ((0, 0), (0, 0), (0, pad_hi - Lk), (0, 0)))
        else:
            kf_p, vf_p = kf, vf

        def body(carry, ki, kf_p=kf_p, vf_p=vf_p, q_blk=q_blk, q0=q0, lo=lo):
            m, l, acc = carry
            k0 = lo + ki * k_chunk
            k_blk = jax.lax.dynamic_slice_in_dim(kf_p, k0, k_chunk, axis=2)
            v_blk = jax.lax.dynamic_slice_in_dim(vf_p, k0, k_chunk, axis=2)
            out = _attn_block(q_blk, k_blk, v_blk, m, l, acc, q0, k0,
                              causal=causal, window=window,
                              kv_offset=kv_offset, kv_len=Lk, scale=scale)
            return out, None

        m0 = jnp.full((B, H, qc, 1), _NEG, jnp.float32)
        l0 = jnp.zeros((B, H, qc, 1), jnp.float32)
        a0 = jnp.zeros((B, H, qc, D), jnp.float32)
        if n_k == 1:                             # decode fast path: no scan
            (m, l, acc), _ = body((m0, l0, a0), 0)
        else:
            (m, l, acc), _ = analysis.scan(body, (m0, l0, a0),
                                           jnp.arange(n_k))
        outs.append((acc / jnp.maximum(l, 1e-30)).astype(q.dtype))
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=2)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, act: str):
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d, d_ff),
         "down": dense_init(ks[1], d_ff, d)}
    if act == "silu":                          # gated (SwiGLU)
        p["gate"] = dense_init(ks[2], d, d_ff)
    return p


def mlp_apply(p: Params, x: jnp.ndarray, act: str) -> jnp.ndarray:
    up = x @ p["up"]
    if act == "silu":
        up = jax.nn.silu(x @ p["gate"]) * up
    else:
        up = jax.nn.gelu(up)
    return up @ p["down"]
