"""repro.models — the 10 assigned architectures as functional JAX models."""
from . import common, mamba2, registry, rglru, transformer, whisper
from .registry import (abstract_cache, abstract_params, decode_step, forward,
                       init_cache, init_params, make_inputs, module)

__all__ = ["common", "mamba2", "registry", "rglru", "transformer", "whisper",
           "abstract_cache", "abstract_params", "decode_step", "forward",
           "init_cache", "init_params", "make_inputs", "module"]
