"""Whisper-base: encoder-decoder with a stubbed conv frontend.

Per the brief, the modality frontend is a STUB — ``input_specs()`` supplies
precomputed frame embeddings [B, frames, d_model] (what the two conv layers
would produce). The transformer backbone is real: a bidirectional encoder
and a causal decoder with cross-attention, learned positional embeddings,
pre-LN, GELU MLPs (the Whisper architecture, arXiv:2212.04356).

Decode caches: per-layer self-attention K/V (grows with generated tokens)
plus the cross-attention K/V computed once from the encoder output.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import attention, dense_init, mlp_apply, mlp_init, rms_norm, stack_init
from . import analysis

Params = Dict[str, Any]


def _mha_init(key, cfg: ModelConfig):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], d, d), "wk": dense_init(ks[1], d, d),
            "wv": dense_init(ks[2], d, d), "wo": dense_init(ks[3], d, d)}


def _heads(cfg, x):
    B, L, _ = x.shape
    return x.reshape(B, L, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)


def _mha(p, cfg, x, kv, *, causal):
    """x attends to kv (self-attention when kv is x)."""
    q = _heads(cfg, x @ p["wq"])
    k = _heads(cfg, kv @ p["wk"])
    v = _heads(cfg, kv @ p["wv"])
    o = attention(q, k, v, causal=causal)
    B, L = x.shape[:2]
    return o.transpose(0, 2, 1, 3).reshape(B, L, -1) @ p["wo"]


def _enc_layer_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.ones((cfg.d_model,)), "ln2": jnp.ones((cfg.d_model,)),
            "attn": _mha_init(k1, cfg),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, "gelu")}


def _dec_layer_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": jnp.ones((cfg.d_model,)), "ln2": jnp.ones((cfg.d_model,)),
            "ln3": jnp.ones((cfg.d_model,)),
            "self": _mha_init(k1, cfg), "cross": _mha_init(k2, cfg),
            "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, "gelu")}


def init_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 5)
    # Position table sized to the assigned shape grid (decode_32k /
    # prefill_32k); the real whisper-base caps at 448 decoder positions —
    # we scale the learned table, everything else is the published config.
    return {
        "embed": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model)) * 0.02,
        "pos_dec": jax.random.normal(ks[1], (40960, cfg.d_model)) * 0.01,
        "pos_enc": jax.random.normal(ks[2], (cfg.encoder_frames,
                                             cfg.d_model)) * 0.01,
        "enc_layers": stack_init(ks[3], cfg.encoder_layers,
                                 lambda k: _enc_layer_init(k, cfg)),
        "dec_layers": stack_init(ks[4], cfg.n_layers,
                                 lambda k: _dec_layer_init(k, cfg)),
        "ln_enc": jnp.ones((cfg.d_model,)),
        "ln_f": jnp.ones((cfg.d_model,)),
    }


def encode(cfg: ModelConfig, p: Params, frames: jnp.ndarray):
    """frames [B, F, d] (stub conv output) → encoder states [B, F, d]."""
    x = frames + p["pos_enc"][None, : frames.shape[1]]

    def layer(h, lp):
        h = h + _mha(lp["attn"], cfg, rms_norm(h, lp["ln1"], cfg.norm_eps),
                     rms_norm(h, lp["ln1"], cfg.norm_eps), causal=False)
        h = h + mlp_apply(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps),
                          "gelu")
        return h, None

    x, _ = analysis.scan(layer, x, p["enc_layers"])
    return rms_norm(x, p["ln_enc"], cfg.norm_eps)


def forward(cfg: ModelConfig, p: Params, batch, *, remat: bool = True,
            unembed: bool = True):
    """batch: frames [B, F, d] + tokens [B, L]. → (logits, {})."""
    enc = encode(cfg, p, batch["frames"])
    tokens = batch["tokens"]
    L = tokens.shape[1]
    x = p["embed"][tokens] + p["pos_dec"][None, :L]

    def layer(h, lp):
        h = h + _mha(lp["self"], cfg, rms_norm(h, lp["ln1"], cfg.norm_eps),
                     rms_norm(h, lp["ln1"], cfg.norm_eps), causal=True)
        h = h + _mha(lp["cross"], cfg, rms_norm(h, lp["ln2"], cfg.norm_eps),
                     enc, causal=False)
        h = h + mlp_apply(lp["mlp"], rms_norm(h, lp["ln3"], cfg.norm_eps),
                          "gelu")
        return h, None

    fn = jax.checkpoint(layer) if remat else layer
    x, _ = analysis.scan(fn, x, p["dec_layers"])
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    return (x @ p["embed"].T if unembed else x), {}


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    return {
        "k": jnp.zeros((cfg.n_layers, batch, cfg.n_heads, max_len,
                        cfg.head_dim), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, cfg.n_heads, max_len,
                        cfg.head_dim), dtype),
        # cross K/V are computed once per request from the encoder output.
        "xk": jnp.zeros((cfg.n_layers, batch, cfg.n_heads,
                         cfg.encoder_frames, cfg.head_dim), dtype),
        "xv": jnp.zeros((cfg.n_layers, batch, cfg.n_heads,
                         cfg.encoder_frames, cfg.head_dim), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


def prime_cache(cfg: ModelConfig, p: Params, cache: Params,
                frames: jnp.ndarray) -> Params:
    """Fill the cross-attention K/V from the encoder (once per request)."""
    enc = encode(cfg, p, frames)

    def per_layer(lp):
        return (_heads(cfg, enc @ lp["cross"]["wk"]),
                _heads(cfg, enc @ lp["cross"]["wv"]))

    xk, xv = jax.vmap(per_layer)(p["dec_layers"])
    return {**cache, "xk": xk.astype(cache["xk"].dtype),
            "xv": xv.astype(cache["xv"].dtype)}


def decode_step(cfg: ModelConfig, p: Params, cache: Params, token):
    idx = cache["idx"]
    pos = jax.lax.dynamic_slice_in_dim(p["pos_dec"], idx, 1, axis=0)  # [1,d]
    x = p["embed"][token] + pos[None]

    def layer(h, inp):
        lp, kc, vc, xk, xv = inp
        hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
        q = _heads(cfg, hn @ lp["self"]["wq"])
        k_t = _heads(cfg, hn @ lp["self"]["wk"])
        v_t = _heads(cfg, hn @ lp["self"]["wv"])
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k_t.astype(kc.dtype),
                                                 idx, axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v_t.astype(vc.dtype),
                                                 idx, axis=2)
        Lc = kc.shape[2]
        logits = jnp.einsum("bhqd,bhld->bhql", q, kc.astype(q.dtype),
                            preferred_element_type=jnp.float32) \
            * cfg.head_dim ** -0.5
        valid = jnp.arange(Lc) <= idx
        logits = jnp.where(valid[None, None, None], logits, -1e30)
        o = jnp.einsum("bhql,bhld->bhqd",
                       jax.nn.softmax(logits, -1).astype(h.dtype),
                       vc.astype(h.dtype))
        B = h.shape[0]
        h = h + o.transpose(0, 2, 1, 3).reshape(B, 1, -1) @ lp["self"]["wo"]

        hn = rms_norm(h, lp["ln2"], cfg.norm_eps)
        q = _heads(cfg, hn @ lp["cross"]["wq"])
        logits = jnp.einsum("bhqd,bhld->bhql", q, xk.astype(q.dtype),
                            preferred_element_type=jnp.float32) \
            * cfg.head_dim ** -0.5
        o = jnp.einsum("bhql,bhld->bhqd",
                       jax.nn.softmax(logits, -1).astype(h.dtype),
                       xv.astype(h.dtype))
        h = h + o.transpose(0, 2, 1, 3).reshape(B, 1, -1) @ lp["cross"]["wo"]
        h = h + mlp_apply(lp["mlp"], rms_norm(h, lp["ln3"], cfg.norm_eps),
                          "gelu")
        return h, (kc, vc)

    x, (k_new, v_new) = analysis.scan(
        layer, x, (p["dec_layers"], cache["k"], cache["v"], cache["xk"],
                   cache["xv"]))
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    return x @ p["embed"].T, {**cache, "k": k_new, "v": v_new, "idx": idx + 1}
