"""Decoder-only transformer family: dense GQA, MoE, and the VLM backbone.

One implementation covers qwen2-7b, granite-3-8b, smollm-135m, tinyllama-1.1b
(dense), dbrx-132b, qwen3-moe-235b-a22b (MoE), and qwen2-vl-2b (VLM backbone;
the vision frontend is a stub that supplies pre-computed patch embeddings and
M-RoPE position streams).

MoE uses capacity-based dispatch (GShard-style, top-k with token dropping)
grouped into fixed-size token blocks so the [g, E, cap] dispatch tensor stays
VMEM-friendly and the expert dim shards cleanly (EP). Two routers:

* ``topk``   — softmax top-k with renormalized gates + aux load-balance loss
               (the published configs' router; the faithful baseline);
* ``dodoor`` — the paper's technique applied to expert routing: candidates
               are drawn from the top-2k gate probabilities, paired, and the
               member with the lower *cached* expert load wins (power-of-two
               on a stale view). The load cache refreshes once per token
               group — exactly the b-batched model with b = group size.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import (apply_mrope, apply_rope, attention, dense_init,
                     mlp_apply, mlp_init, rms_norm, stack_init,
                     text_positions3)
from . import analysis
from . import precision

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# attention sublayer
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd),
        "wk": dense_init(ks[1], d, cfg.n_kv * hd),
        "wv": dense_init(ks[2], d, cfg.n_kv * hd),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,))
        p["bk"] = jnp.zeros((cfg.n_kv * hd,))
        p["bv"] = jnp.zeros((cfg.n_kv * hd,))
    return p


def _qkv(p, x, cfg: ModelConfig):
    B, L, _ = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"] + (p["bq"] if cfg.qkv_bias else 0.0)
    k = x @ p["wk"] + (p["bk"] if cfg.qkv_bias else 0.0)
    v = x @ p["wv"] + (p["bv"] if cfg.qkv_bias else 0.0)
    q = q.reshape(B, L, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(B, L, cfg.n_kv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, L, cfg.n_kv, hd).transpose(0, 2, 1, 3)
    return q, k, v


def attn_apply(p, x, cfg: ModelConfig, positions, *, causal=True,
               window=None, positions3=None):
    """Full-sequence (train/prefill) attention sublayer."""
    B, L, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    if cfg.mrope and positions3 is not None:
        q = apply_mrope(q, positions3, cfg.rope_theta)
        k = apply_mrope(k, positions3, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    o = attention(q, k, v, causal=causal, window=window)
    return o.transpose(0, 2, 1, 3).reshape(B, L, -1) @ p["wo"], (k, v)


def attn_decode(p, x_t, cfg: ModelConfig, k_cache, v_cache, idx, *,
                window=None, positions3_t=None):
    """One-token decode: x_t [B, 1, d]; caches [B, n_kv, L, hd]; ``idx`` is
    the write position (traced). Returns (out [B,1,d], k_cache, v_cache)."""
    B = x_t.shape[0]
    hd = cfg.head_dim
    q, k_t, v_t = _qkv(p, x_t, cfg)
    pos = jnp.full((B, 1), idx, jnp.int32)
    if cfg.mrope and positions3_t is not None:
        q = apply_mrope(q, positions3_t, cfg.rope_theta)
        k_t = apply_mrope(k_t, positions3_t, cfg.rope_theta)
    else:
        q = apply_rope(q, pos, cfg.rope_theta)
        k_t = apply_rope(k_t, pos, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_t, idx, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_t, idx, axis=2)

    # One einsum over the cache; mask invalid (future) slots and the window.
    L = k_cache.shape[2]
    rep = cfg.n_heads // cfg.n_kv
    qg = q.reshape(B, cfg.n_kv, rep, hd)
    logits = jnp.einsum("bgrd,bgld->bgrl", qg, k_cache,
                        preferred_element_type=jnp.float32) * hd ** -0.5
    k_pos = jnp.arange(L)
    valid = k_pos <= idx
    if window is not None:
        valid &= k_pos > idx - window
    logits = jnp.where(valid[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bgrl,bgld->bgrd", probs, v_cache)
    o = o.reshape(B, 1, cfg.n_heads * hd)
    return o @ p["wo"], k_cache, v_cache


# ---------------------------------------------------------------------------
# MoE sublayer
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig):
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    return {
        "router": dense_init(ks[0], d, E, scale=0.02),
        "w_gate": jax.random.normal(ks[1], (E, d, ff)) * scale,
        "w_up": jax.random.normal(ks[2], (E, d, ff)) * scale,
        "w_down": jax.random.normal(ks[3], (E, ff, d)) * (ff ** -0.5),
    }


def _capacity(g: int, cfg: ModelConfig) -> int:
    return max(1, int(g * cfg.top_k * cfg.capacity_factor) // cfg.n_experts)


def _route_topk(probs, k):
    vals, idx = jax.lax.top_k(probs, k)
    vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    return idx, vals


def _route_dodoor(probs, load, k):
    """Power-of-two expert choice on a cached load view (the paper's
    Algorithm 1 adapted to routing): prefilter = top-2k gate probs; pair
    (2i, 2i+1); the pair member with lower cached load wins (RL score with a
    single resource dim and α=0 — expert 'duration' is uniform)."""
    _, cand = jax.lax.top_k(probs, 2 * k)                 # [g, 2k]
    ca, cb = cand[:, 0::2], cand[:, 1::2]                 # [g, k] each
    la, lb = load[ca], load[cb]
    idx = jnp.where(lb < la, cb, ca)                      # ties → A (higher p)
    vals = jnp.take_along_axis(probs, idx, axis=-1)
    vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    return idx, vals


def moe_group_apply(p, x, cfg: ModelConfig, load):
    """One token group. x [g, d]; load [E] cached expert loads (dodoor).
    Returns (y [g, d], aux scalar, new_load [E])."""
    E, k = cfg.n_experts, cfg.top_k
    g = x.shape[0]
    cap = _capacity(g, cfg)
    logits = (x @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)               # [g, E]
    if cfg.router == "dodoor":
        idx, vals = _route_dodoor(probs, load, k)
    else:
        idx, vals = _route_topk(probs, k)

    eoh = jax.nn.one_hot(idx, E, dtype=jnp.float32)       # [g, k, E]
    # Position of each (token, choice) in its expert's queue; token-major,
    # choice-minor priority.
    flat = eoh.reshape(g * k, E)
    pos = jnp.cumsum(flat, axis=0) - flat                 # [g·k, E]
    pos = jnp.sum(pos * flat, axis=-1).reshape(g, k).astype(jnp.int32)
    keep = pos < cap
    poh = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * keep[..., None]
    dispatch = jnp.einsum("gke,gkc->gec", eoh, poh)       # [g, E, cap]
    combine = jnp.einsum("gke,gkc,gk->gec", eoh, poh,
                         vals.astype(jnp.float32))

    xe = jnp.einsum("gec,gd->ecd", dispatch.astype(x.dtype), x)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    y = jnp.einsum("gec,ecd->gd", combine.astype(x.dtype), ye)

    # Aux load-balance loss (Switch): E · Σ_e f_e · P_e.
    f = jnp.mean(eoh.sum(1), axis=0)                      # fraction per expert
    P = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * P)
    new_load = eoh.sum((0, 1))                            # tokens per expert
    return y, aux, new_load


def moe_apply(p, x, cfg: ModelConfig, group: int = 2048):
    """x [B, L, d] → (y, aux). Token groups are scanned sequentially; the
    dodoor router's load cache refreshes once per group (b-batched)."""
    B, L, d = x.shape
    T = B * L
    xt = x.reshape(T, d)
    g = min(group, T)
    pad = (-T) % g
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    xg = xt.reshape(-1, g, d)

    def body(load, xg_i):
        y, aux, new_load = moe_group_apply(p, xg_i, cfg, load)
        return new_load, (y, aux)

    load0 = jnp.zeros((cfg.n_experts,), jnp.float32)
    _, (yg, auxs) = analysis.scan(body, load0, xg)
    y = yg.reshape(-1, d)[:T].reshape(B, L, d)
    return y, jnp.mean(auxs)


# ---------------------------------------------------------------------------
# the decoder stack
# ---------------------------------------------------------------------------

def layer_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": jnp.ones((cfg.d_model,)),
        "ln2": jnp.ones((cfg.d_model,)),
        "attn": attn_init(ks[0], cfg),
    }
    if cfg.is_moe:
        p["moe"] = moe_init(ks[1], cfg)
    else:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act)
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 3)
    p = {
        "embed": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model)) * 0.02,
        "layers": stack_init(ks[1], cfg.n_layers,
                             lambda k: layer_init(k, cfg)),
        "ln_f": jnp.ones((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.vocab, scale=0.02)
    if cfg.family == "vlm":
        # Stub patch-projection so vision tokens are a first-class input.
        p["patch_proj"] = dense_init(ks[2], cfg.d_model, cfg.d_model)
    return p


def _unembed(cfg, p, x):
    if cfg.tie_embeddings:
        return x @ p["embed"].T
    return x @ p["lm_head"]


def forward(cfg: ModelConfig, p: Params, batch: Dict[str, jnp.ndarray],
            *, remat: bool = True, unembed: bool = True):
    """Training/prefill forward → (logits [B, L, V], aux dict).

    batch: tokens [B, L] (for vlm: patches [B, n_patches, d] + positions3
    [B, 3, L_total]; tokens then cover L_total − n_patches positions).
    """
    p = precision.cast_params(p)       # bf16-at-use: gathers move bf16
    tokens = batch["tokens"]
    x = precision.cast_act(p["embed"][tokens])
    B = x.shape[0]
    positions3 = None
    if cfg.family == "vlm":
        patches = batch["patches"] @ p["patch_proj"]
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        positions3 = batch.get("positions3")
    L = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    if cfg.mrope and positions3 is None:
        positions3 = text_positions3(positions)

    def layer_fn(carry, lp):
        h, aux = carry
        h = precision.constrain(h)              # SP residual sharding
        a, _ = attn_apply(lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps),
                          cfg, positions, window=cfg.window,
                          positions3=positions3)
        h = precision.constrain(h + a)
        if cfg.is_moe:
            f, aux_i = moe_apply(lp["moe"],
                                 rms_norm(h, lp["ln2"], cfg.norm_eps), cfg)
            aux = aux + aux_i
        else:
            f = mlp_apply(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps),
                          cfg.act)
        return (precision.constrain(h + f), aux), None

    fn = jax.checkpoint(layer_fn) if remat else layer_fn
    (x, aux), _ = analysis.scan(fn, (x, jnp.float32(0.0)), p["layers"])
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    out = _unembed(cfg, p, x) if unembed else x
    return out, {"moe_aux": aux / max(cfg.n_layers, 1)}


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    shape = (cfg.n_layers, batch, cfg.n_kv, max_len, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "idx": jnp.zeros((), jnp.int32)}


def decode_step(cfg: ModelConfig, p: Params, cache: Params,
                token: jnp.ndarray):
    """token [B, 1] int32 → (logits [B, 1, V], cache')."""
    x = p["embed"][token]
    idx = cache["idx"]

    def layer_fn(h, inp):
        lp, kc, vc = inp
        a, kc, vc = attn_decode(lp["attn"],
                                rms_norm(h, lp["ln1"], cfg.norm_eps), cfg,
                                kc.astype(h.dtype), vc.astype(h.dtype), idx,
                                window=cfg.window)
        h = h + a
        if cfg.is_moe:
            f, _ = moe_apply(lp["moe"], rms_norm(h, lp["ln2"], cfg.norm_eps),
                             cfg)
        else:
            f = mlp_apply(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps),
                          cfg.act)
        return h + f, (kc.astype(cache["k"].dtype),
                       vc.astype(cache["v"].dtype))

    x, (k_new, v_new) = analysis.scan(layer_fn, x,
                                      (p["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    logits = _unembed(cfg, p, x)
    return logits, {"k": k_new, "v": v_new, "idx": idx + 1}
