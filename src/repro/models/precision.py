"""Perf knobs threaded through the models (the §Perf hillclimb levers).

* ``compute_dtype`` — cast layer weights + residual stream to bf16 at use
  (f32 master params stay in the optimizer). Halves every activation
  collective and weight gather on the wire.
* ``residual_spec`` — a PartitionSpec applied to the residual stream between
  sublayers (Megatron-style sequence parallelism when set to
  P(data_axes, 'model', None)): XLA converts the TP all-reduce pairs into
  reduce-scatter + all-gather, halving wire bytes per pair.

Both are trace-time globals (like models.analysis): the launcher sets them
per cell; defaults preserve the paper-faithful baseline exactly.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
import jax.numpy as jnp

_DTYPE: Optional[jnp.dtype] = None
_RESIDUAL_SPEC = None


def set_compute_dtype(dtype) -> None:
    global _DTYPE
    _DTYPE = dtype


def set_residual_spec(spec) -> None:
    global _RESIDUAL_SPEC
    _RESIDUAL_SPEC = spec


@contextlib.contextmanager
def options(dtype=None, residual_spec=None):
    global _DTYPE, _RESIDUAL_SPEC
    old = (_DTYPE, _RESIDUAL_SPEC)
    _DTYPE, _RESIDUAL_SPEC = dtype, residual_spec
    try:
        yield
    finally:
        _DTYPE, _RESIDUAL_SPEC = old


def cast_params(tree):
    """Cast float leaves of a layer-param pytree to the compute dtype."""
    if _DTYPE is None:
        return tree
    return jax.tree.map(
        lambda a: a.astype(_DTYPE)
        if hasattr(a, "dtype") and jnp.issubdtype(a.dtype, jnp.floating)
        else a, tree)


def cast_act(x):
    return x if _DTYPE is None else x.astype(_DTYPE)


def constrain(x):
    if _RESIDUAL_SPEC is None:
        return x
    return jax.lax.with_sharding_constraint(x, _RESIDUAL_SPEC)
