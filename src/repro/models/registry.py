"""Family dispatch + input specs — the single entry point the launcher,
dry-run, trainer, and server use to talk to any of the 10 architectures.

``input_specs(cfg, shape, abstract=True)`` returns ShapeDtypeStruct stand-ins
(weak-type-correct, shardable, no device allocation) for every model input of
the given shape cell — train batches for ``train_*``, a one-token decode
batch plus the full cache pytree for ``decode_*`` / ``long_*``.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..configs.shapes import ShapeSpec
from . import mamba2, rglru, transformer, whisper

Params = Dict[str, Any]

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": mamba2,
    "hybrid": rglru,
    "audio": whisper,
}


def module(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def init_params(cfg: ModelConfig, key) -> Params:
    return module(cfg).init_params(cfg, key)


def abstract_params(cfg: ModelConfig) -> Params:
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def forward(cfg: ModelConfig, params, batch, **kw):
    return module(cfg).forward(cfg, params, batch, **kw)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, **kw):
    return module(cfg).init_cache(cfg, batch, max_len, **kw)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    kw = {"dtype": dtype} if dtype is not None else {}
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, **kw))


def decode_step(cfg: ModelConfig, params, cache, token):
    return module(cfg).decode_step(cfg, params, cache, token)


# ---------------------------------------------------------------------------
# per-cell input specs
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, B: int, L: int) -> Dict[str, Any]:
    """Inputs for train_step/prefill: tokens + labels (+ modality stubs)."""
    specs: Dict[str, Any] = {}
    if cfg.family == "vlm":
        n_p = min(cfg.vision_patches, max(1, L // 4))
        specs["patches"] = _sds((B, n_p, cfg.d_model), jnp.bfloat16)
        specs["tokens"] = _sds((B, L - n_p), jnp.int32)
        specs["positions3"] = _sds((B, 3, L), jnp.int32)
        specs["labels"] = _sds((B, L - n_p), jnp.int32)
    elif cfg.family == "audio":
        specs["frames"] = _sds((B, cfg.encoder_frames, cfg.d_model),
                               jnp.bfloat16)
        specs["tokens"] = _sds((B, L), jnp.int32)
        specs["labels"] = _sds((B, L), jnp.int32)
    else:
        specs["tokens"] = _sds((B, L), jnp.int32)
        specs["labels"] = _sds((B, L), jnp.int32)
    return specs


def decode_specs(cfg: ModelConfig, B: int, L: int, cache_dtype=None):
    """(cache specs, token spec) for one serve_step against an L-token
    context."""
    cache = abstract_cache(cfg, B, L, dtype=cache_dtype)
    token = _sds((B, 1), jnp.int32)
    return cache, token


def make_inputs(cfg: ModelConfig, shape: ShapeSpec, *, concrete: bool = False,
                seed: int = 0, cache_dtype=None):
    """Inputs for a shape cell. abstract (default) → ShapeDtypeStructs;
    concrete → small real arrays (smoke tests only — full shapes would
    allocate)."""
    B, L = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        specs = train_batch_specs(cfg, B, L)
    else:
        cache, token = decode_specs(cfg, B, L, cache_dtype=cache_dtype)
        specs = {"cache": cache, "token": token}
    if not concrete:
        return specs
    rng = np.random.RandomState(seed)

    def realize(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jnp.asarray(rng.randint(0, max(2, cfg.vocab // 2),
                                           size=s.shape), s.dtype)
        return jnp.asarray(rng.randn(*s.shape), s.dtype) * 0.02

    return jax.tree.map(realize, specs)
