"""Mamba-2 (SSD — state-space duality), attention-free LM.

The mixer follows arXiv:2405.21060: fused in-projection → short causal
depthwise conv → SSD recurrence (chunked; see repro.kernels.ssd_chunk for
the Pallas TPU version of the intra-chunk block) → skip (D), gate (z·silu),
grouped RMSNorm → out-projection.

The jnp SSD here scans over chunks (one [B,H,Q,Q] decay-masked matmul per
step, an [B,H,S,P] state carried) — compiled memory stays flat in sequence
length, which is what makes the ``long_500k`` decode/prefill cells lowerable.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import dense_init, rms_norm, stack_init
from . import analysis

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# chunked SSD (jnp; validated against kernels.ssd_chunk's oracle in tests)
# ---------------------------------------------------------------------------

def ssd_scan(x, dt, A, Bm, Cm, h0=None, *, chunk: int = 64):
    """x [B,L,H,P]; dt [B,L,H] (>0); A [H] (<0); Bm/Cm [B,L,G,S].
    Returns (y [B,L,H,P], h_final [B,H,S,P])."""
    B, L, H, P = x.shape
    G, S = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    NC = Lp // Q

    xc = x.reshape(B, NC, Q, H, P).transpose(1, 0, 2, 3, 4)     # [NC,B,Q,H,P]
    dtc = dt.reshape(B, NC, Q, H).transpose(1, 0, 2, 3)
    Bc = Bm.reshape(B, NC, Q, G, S).transpose(1, 0, 2, 3, 4)
    Cc = Cm.reshape(B, NC, Q, G, S).transpose(1, 0, 2, 3, 4)

    tri = jnp.tril(jnp.ones((Q, Q), bool))                       # u ≤ t

    if h0 is None:
        h0 = jnp.zeros((B, H, S, P), jnp.float32)

    def step(h, inp):
        xq, dtq, Bq, Cq = inp            # [B,Q,H,P], [B,Q,H], [B,Q,G,S] ×2
        delta = dtq * A[None, None, :]                  # [B,Q,H] (negative)
        s = jnp.cumsum(delta, axis=1)                   # inclusive
        # intra-chunk: G_mat[b,h,t,u] = (C_t·B_u)·exp(s_t−s_u)·dt_u, u ≤ t
        CB = jnp.einsum("btgs,bugs->bgtu", Cq, Bq)      # [B,G,Q,Q]
        CBh = jnp.repeat(CB, hpg, axis=1)               # [B,H,Q,Q]
        # diff ≤ 0 on the valid (u ≤ t) triangle; clamp the masked region so
        # exp never overflows (0·inf = NaN in the where-gradient otherwise).
        diff = jnp.minimum(s[:, :, None] - s[:, None], 0.0)  # [B,Q,Q,H]
        M = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        Gm = CBh * M.transpose(0, 3, 1, 2) * dtq.transpose(0, 2, 1)[:, :, None]
        y = jnp.einsum("bhtu,buhp->bthp", Gm, xq)
        # h_in correction + chunk state update
        es = jnp.exp(s)                                 # [B,Q,H]
        Ch = jnp.repeat(Cq, hpg, axis=2)                # [B,Q,H,S]
        y = y + jnp.einsum("bths,bhsp->bthp", Ch, h) * es[..., None]
        w = jnp.exp(s[:, -1:, :] - s) * dtq             # [B,Q,H]
        Bh = jnp.repeat(Bq, hpg, axis=2)                # [B,Q,H,S]
        decay = jnp.exp(jnp.sum(delta, axis=1))         # [B,H]
        h = decay[:, :, None, None] * h + jnp.einsum(
            "buhs,buh,buhp->bhsp", Bh, w, xq)
        return h, y

    h, ys = analysis.scan(step, h0, (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, Lp, H, P)[:, :L]
    return y, h


def ssd_step(x_t, dt_t, A, B_t, C_t, h):
    """Single-token SSD update: x_t [B,H,P], dt_t [B,H], B_t/C_t [B,G,S],
    h [B,H,S,P] → (y_t [B,H,P], h')."""
    H = x_t.shape[1]
    G = B_t.shape[1]
    rep = H // G
    Bh = jnp.repeat(B_t, rep, axis=1)
    Ch = jnp.repeat(C_t, rep, axis=1)
    decay = jnp.exp(A[None, :] * dt_t)
    h = (decay[..., None, None] * h
         + dt_t[..., None, None] * Bh[..., None] * x_t[:, :, None, :])
    return jnp.einsum("bhs,bhsp->bhp", Ch, h), h


# ---------------------------------------------------------------------------
# the mixer layer
# ---------------------------------------------------------------------------

def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_headdim
    conv_dim = d_in + 2 * cfg.ssm_groups * cfg.ssm_state
    return d_in, H, conv_dim


def mixer_init(key, cfg: ModelConfig):
    d = cfg.d_model
    d_in, H, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in + 2 * cfg.ssm_groups
                              * cfg.ssm_state + H),
        "conv_w": jax.random.normal(ks[1], (cfg.conv_kernel, conv_dim)) * 0.1,
        "conv_b": jnp.zeros((conv_dim,)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),
        "D": jnp.ones((H,)),
        "dt_bias": jnp.zeros((H,)) - 1.0,
        "norm": jnp.ones((d_in,)),
        "out_proj": dense_init(ks[2], d_in, d),
    }


def _split_proj(cfg, zxbcdt):
    d_in, H, _ = _dims(cfg)
    gs = cfg.ssm_groups * cfg.ssm_state
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:d_in + d_in + 2 * gs]
    dt = zxbcdt[..., -H:]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv over time. xBC [B,L,C]; w [K,C]."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def mixer_apply(p, x, cfg: ModelConfig, *, chunk: int = 64):
    """Full-sequence mixer. x [B,L,d] → [B,L,d]."""
    B, L, _ = x.shape
    d_in, H, _ = _dims(cfg)
    G, S, P = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_headdim
    z, xBC, dt = _split_proj(cfg, x @ p["in_proj"])
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xs = xBC[..., :d_in].reshape(B, L, H, P)
    Bm = xBC[..., d_in:d_in + G * S].reshape(B, L, G, S)
    Cm = xBC[..., d_in + G * S:].reshape(B, L, G, S)
    dt = jax.nn.softplus(dt + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_scan(xs.astype(jnp.float32), dt.astype(jnp.float32), A,
                    Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                    chunk=chunk)
    y = y.astype(x.dtype) + p["D"][None, None, :, None] * xs
    y = y.reshape(B, L, d_in) * jax.nn.silu(z)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    return y @ p["out_proj"]


def mixer_decode(p, x_t, cfg: ModelConfig, conv_state, ssm_state):
    """One-token mixer. x_t [B,1,d]; conv_state [B,K−1,conv_dim];
    ssm_state [B,H,S,P]."""
    B = x_t.shape[0]
    d_in, H, conv_dim = _dims(cfg)
    G, S, P = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_headdim
    z, xBC, dt = _split_proj(cfg, x_t @ p["in_proj"])
    xBC = xBC[:, 0]                                     # [B, conv_dim]
    window = jnp.concatenate([conv_state, xBC[:, None]], axis=1)  # [B,K,C]
    conv_state = window[:, 1:]
    out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xBC = jax.nn.silu(out)
    xs = xBC[..., :d_in].reshape(B, H, P)
    Bm = xBC[..., d_in:d_in + G * S].reshape(B, G, S)
    Cm = xBC[..., d_in + G * S:].reshape(B, G, S)
    dtv = jax.nn.softplus(dt[:, 0] + p["dt_bias"])      # [B, H]
    A = -jnp.exp(p["A_log"])
    y, ssm_state = ssd_step(xs.astype(jnp.float32), dtv.astype(jnp.float32),
                            A, Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                            ssm_state)
    y = y.astype(x_t.dtype) + p["D"][None, :, None] * xs
    y = y.reshape(B, 1, d_in) * jax.nn.silu(z)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], conv_state, ssm_state


# ---------------------------------------------------------------------------
# the LM
# ---------------------------------------------------------------------------

def layer_init(key, cfg: ModelConfig):
    return {"ln": jnp.ones((cfg.d_model,)), "mixer": mixer_init(key, cfg)}


def init_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "embed": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model)) * 0.02,
        "layers": stack_init(ks[1], cfg.n_layers,
                             lambda k: layer_init(k, cfg)),
        "ln_f": jnp.ones((cfg.d_model,)),
    }


def forward(cfg: ModelConfig, p: Params, batch, *, remat: bool = True,
            unembed: bool = True):
    x = p["embed"][batch["tokens"]]

    def layer_fn(h, lp):
        return h + mixer_apply(lp["mixer"], rms_norm(h, lp["ln"],
                                                     cfg.norm_eps), cfg), None

    fn = jax.checkpoint(layer_fn) if remat else layer_fn
    x, _ = analysis.scan(fn, x, p["layers"])
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    return (x @ p["embed"].T if unembed else x), {}


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.float32) -> Params:
    d_in, H, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_kernel - 1,
                           conv_dim), dtype),
        "ssm": jnp.zeros((cfg.n_layers, batch, H, cfg.ssm_state,
                          cfg.ssm_headdim), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


def decode_step(cfg: ModelConfig, p: Params, cache: Params, token):
    x = p["embed"][token]

    def layer_fn(h, inp):
        lp, cs, ss = inp
        y, cs, ss = mixer_decode(lp["mixer"],
                                 rms_norm(h, lp["ln"], cfg.norm_eps), cfg,
                                 cs.astype(jnp.float32),
                                 ss.astype(jnp.float32))
        return h + y, (cs.astype(cache["conv"].dtype),
                       ss.astype(cache["ssm"].dtype))

    x, (conv, ssm) = analysis.scan(layer_fn, x,
                                   (p["layers"], cache["conv"], cache["ssm"]))
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    return x @ p["embed"].T, {"conv": conv, "ssm": ssm,
                              "idx": cache["idx"] + 1}
