"""Sharded, atomic, resumable checkpointing (no orbax offline).

Layout per step:

    <dir>/step_000123/
        manifest.json         # pytree structure, shapes, dtypes, host count
        shard_00000.npz       # this host's param shards, flat-key → array

Production properties kept at miniature scale:

* **Atomicity** — writes go to ``step_N.tmp/`` and are renamed into place
  only after the manifest lands; a crash mid-write never corrupts the
  latest complete checkpoint (restore scans for the newest *complete* dir).
* **Host-sharded** — each host saves only the addressable shards of its
  arrays (``jax.experimental.multihost_utils`` semantics degenerate to a
  single shard on one host); restore reassembles per the manifest.
* **Elastic restore** — the manifest records logical shapes, not device
  layouts, so a checkpoint written on a (16, 16) mesh restores onto a
  (8, 16) survivor mesh (repro.ft.elastic) by resharding at load.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_fields"):
        for f in tree._fields:
            out.update(_flatten(getattr(tree, f), f"{prefix}{f}/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _unflatten_like(template: Any, flat: dict, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_like(v, flat, f"{prefix}{k}/")
                for k, v in template.items()}
    if isinstance(template, (tuple, list)) and not hasattr(template, "_fields"):
        seq = [_unflatten_like(v, flat, f"{prefix}{i}/")
               for i, v in enumerate(template)]
        return type(template)(seq)
    if hasattr(template, "_fields"):
        return type(template)(*[
            _unflatten_like(getattr(template, f), flat, f"{prefix}{f}/")
            for f in template._fields])
    return flat[prefix.rstrip("/")]


def latest_step(directory: str | Path) -> Optional[int]:
    d = Path(directory)
    if not d.exists():
        return None
    steps = []
    for p in d.iterdir():
        if p.is_dir() and p.name.startswith("step_") \
                and (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


class Checkpointer:
    def __init__(self, directory: str | Path, *, keep: int = 3,
                 host_index: int = 0, num_hosts: int = 1):
        self.dir = Path(directory)
        self.keep = keep
        self.host = host_index
        self.num_hosts = num_hosts
        self.dir.mkdir(parents=True, exist_ok=True)

    def save(self, step: int, tree: Any) -> Path:
        flat = _flatten(tree)
        final = self.dir / f"step_{step:06d}"
        tmp = self.dir / f"step_{step:06d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        # npz cannot round-trip ml_dtypes (bf16 etc.) — store raw bytes and
        # reconstruct from the manifest's dtype/shape at restore.
        arrays = {k: np.ascontiguousarray(np.asarray(v)).view(np.uint8)
                  .reshape(-1) for k, v in flat.items()}
        np.savez(tmp / f"shard_{self.host:05d}.npz", **arrays)
        manifest = {
            "step": step,
            "num_hosts": self.num_hosts,
            "keys": {k: {"shape": list(np.shape(v)),
                         "dtype": str(np.asarray(v).dtype)}
                     for k, v in flat.items()},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)            # atomic publish
        self._gc()
        return final

    def restore(self, template: Any, step: Optional[int] = None) -> Any:
        step = step if step is not None else latest_step(self.dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self.dir / f"step_{step:06d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat = {}
        for p in sorted(d.glob("shard_*.npz")):
            with np.load(p) as z:
                for k in z.files:
                    flat[k] = z[k]
        missing = set(manifest["keys"]) - set(flat)
        if missing:
            raise IOError(f"checkpoint step {step} incomplete: {missing}")
        import ml_dtypes  # noqa: F401 (registers bf16 etc. with numpy)
        typed = {}
        for k, meta in manifest["keys"].items():
            dt = np.dtype(meta["dtype"])
            typed[k] = flat[k].view(dt).reshape(meta["shape"])
        return _unflatten_like(template, typed), step

    def _gc(self):
        steps = sorted(p for p in self.dir.iterdir()
                       if p.is_dir() and p.name.startswith("step_")
                       and not p.name.endswith(".tmp"))
        for p in steps[: -self.keep]:
            shutil.rmtree(p)
