"""Latency accounting for the decision service.

One :class:`LatencyRecorder` per metric (per-decision scheduling latency,
per-step wall clock): raw samples in milliseconds, summarized as
p50/p95/p99 and bucketed into a log-spaced histogram — the shape
``BENCH_serve.json`` persists and the dashboard's latency panel renders.

Pure numpy (no JAX): recording happens on the host, on the serving hot
path's timing side.
"""
from __future__ import annotations

import numpy as np


class LatencyRecorder:
    """Append-only sample store with percentile + histogram views."""

    def __init__(self):
        self._chunks: list[np.ndarray] = []

    def record(self, samples_ms) -> None:
        a = np.atleast_1d(np.asarray(samples_ms, np.float64))
        if a.size:
            self._chunks.append(a)

    @property
    def count(self) -> int:
        return int(sum(c.size for c in self._chunks))

    def samples(self) -> np.ndarray:
        if not self._chunks:
            return np.zeros((0,), np.float64)
        return np.concatenate(self._chunks)

    def percentile(self, q: float) -> float:
        s = self.samples()
        return float(np.percentile(s, q)) if s.size else float("nan")

    def summary(self) -> dict:
        """``count`` plus mean/p50/p95/p99/max in ms (rounded for the
        bench artifact)."""
        s = self.samples()
        if not s.size:
            return {"count": 0}
        return {
            "count": int(s.size),
            "mean_ms": round(float(np.mean(s)), 4),
            "p50_ms": round(float(np.percentile(s, 50.0)), 4),
            "p95_ms": round(float(np.percentile(s, 95.0)), 4),
            "p99_ms": round(float(np.percentile(s, 99.0)), 4),
            "max_ms": round(float(np.max(s)), 4),
        }

    def histogram(self, nbins: int = 24) -> dict:
        """Log-spaced buckets over the observed range: ``edges_ms`` has
        ``nbins + 1`` entries, ``counts`` has ``nbins``.  Degenerate
        ranges (all samples equal) widen to a ±10% band so the buckets
        stay well-formed."""
        s = self.samples()
        if not s.size:
            return {"edges_ms": [], "counts": []}
        lo = max(float(np.min(s)), 1e-6)
        hi = max(float(np.max(s)), lo)
        if hi <= lo:
            lo, hi = lo * 0.9, hi * 1.1
        edges = np.logspace(np.log10(lo), np.log10(hi), nbins + 1)
        counts, _ = np.histogram(s, bins=edges)
        return {"edges_ms": [round(float(e), 6) for e in edges],
                "counts": [int(c) for c in counts]}
