"""repro.serve — the streaming decision service.

Everything else in the repo replays a pre-materialized arrival plane
offline; this package is the online mode: arrival chunks flow through a
host-side ring buffer, are re-blocked into ``b``-task decision blocks,
and drive one compiled donated-buffer step per block.  The step is the
factored-out single-block body of the batched scan
(``repro.sim.engine._make_block_step``), so replaying the same arrival
plane through the service is bit-exact with ``simulate(mode="batched")``
for every policy — the offline engine is the online engine's
correctness oracle.  See ``docs/SERVING.md``.
"""
from .latency import LatencyRecorder
from .ring import ArrivalRing
from .service import DecisionService, serve_workload

__all__ = ["ArrivalRing", "DecisionService", "LatencyRecorder",
           "serve_workload"]
