"""Host-side arrival ring buffer.

Bounded, preallocated numpy storage for in-flight arrivals: ``push``
appends a chunk (any size), ``pop`` removes exactly the rows a decision
block consumes.  Alongside the five workload planes each task carries
its host enqueue timestamp (``time.perf_counter`` seconds, recorded by
the service at submit), which is what per-decision scheduling latency —
enqueue → placement — is measured from.

Pure numpy: the ring is the host side of the service loop and must not
touch the device (uploads happen once per block, in the service).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class ArrivalRows(NamedTuple):
    """A contiguous batch popped from the ring (copies — the ring slots
    are immediately reusable)."""
    r_submit: np.ndarray    # [k, K]
    r_exec: np.ndarray      # [k, TT, K]
    d_est: np.ndarray       # [k, TT]
    d_act: np.ndarray       # [k, TT]
    submit_ms: np.ndarray   # [k]  virtual trace time
    t_enq: np.ndarray       # [k]  host perf_counter at submit (seconds)


class ArrivalRing:
    """Fixed-capacity FIFO over the workload planes.

    ``capacity`` bounds the number of buffered (submitted but not yet
    scheduled) tasks; pushing past it raises — open-loop callers size it
    to their stream, closed-loop callers need only ``b``.
    """

    def __init__(self, capacity: int, num_types: int, k: int = 2):
        if capacity < 1:
            raise ValueError(f"capacity must be ≥ 1, got {capacity}")
        self.capacity = int(capacity)
        c = self.capacity
        self._r_submit = np.zeros((c, k), np.float32)
        self._r_exec = np.zeros((c, num_types, k), np.float32)
        self._d_est = np.zeros((c, num_types), np.float32)
        self._d_act = np.zeros((c, num_types), np.float32)
        self._submit_ms = np.zeros((c,), np.float32)
        self._t_enq = np.zeros((c,), np.float64)
        self._head = 0          # next row to pop
        self._count = 0

    def __len__(self) -> int:
        return self._count

    @property
    def count(self) -> int:
        return self._count

    @property
    def free(self) -> int:
        return self.capacity - self._count

    def push(self, r_submit, r_exec, d_est, d_act, submit_ms,
             t_enq: float) -> int:
        """Append a chunk; every plane must agree on the chunk length.
        ``t_enq`` (one host timestamp for the whole chunk) is recorded
        per task.  Returns the number of tasks accepted."""
        r_submit = np.asarray(r_submit, np.float32)
        k = r_submit.shape[0]
        if k == 0:
            return 0
        if k > self.free:
            raise RuntimeError(
                f"arrival ring full: {self._count}/{self.capacity} held, "
                f"chunk of {k} rejected — step()/flush() the service, or "
                f"raise DecisionService(capacity=...)")
        rows = (self._head + self._count + np.arange(k)) % self.capacity
        for buf, arr in ((self._r_submit, r_submit),
                         (self._r_exec, np.asarray(r_exec, np.float32)),
                         (self._d_est, np.asarray(d_est, np.float32)),
                         (self._d_act, np.asarray(d_act, np.float32)),
                         (self._submit_ms,
                          np.asarray(submit_ms, np.float32))):
            if arr.shape[0] != k or arr.shape[1:] != buf.shape[1:]:
                raise ValueError(
                    f"chunk plane shape {arr.shape} does not match ring "
                    f"slot {(k,) + buf.shape[1:]}")
            buf[rows] = arr
        self._t_enq[rows] = float(t_enq)
        self._count += k
        return k

    def pop(self, k: int) -> ArrivalRows:
        """Remove and return the oldest ``k`` rows (copies)."""
        if k < 1 or k > self._count:
            raise ValueError(f"pop({k}) from ring holding {self._count}")
        rows = (self._head + np.arange(k)) % self.capacity
        out = ArrivalRows(
            r_submit=self._r_submit[rows],
            r_exec=self._r_exec[rows],
            d_est=self._d_est[rows],
            d_act=self._d_act[rows],
            submit_ms=self._submit_ms[rows],
            t_enq=self._t_enq[rows],
        )
        self._head = (self._head + k) % self.capacity
        self._count -= k
        return out
