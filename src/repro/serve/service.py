"""The streaming decision service: donated-buffer step engine.

:class:`DecisionService` ingests arrival chunks through a host-side ring
buffer (:class:`~repro.serve.ring.ArrivalRing`), re-blocks them into
``b``-task decision blocks, and drives one compiled
``step(carry, block)`` per block.  The step body is the factored-out
single-block body of the offline batched scan
(:func:`repro.sim.engine._make_block_step`), jitted here with
``donate_argnums`` on the carry — ring buffers, unit clocks, cached
views, Prequal pools, and the message ledger are donated back to XLA
every step, so steady-state steps allocate nothing and never recompile
(block shapes are fixed by ``b``; the ragged tail rides a validity mask,
not a new shape).

Bit-exactness contract: feeding the service the same arrival plane as
``simulate(mode="batched")`` — same order, any chunking — yields
bit-identical placements and message ledger for all five policies.  The
service replicates the offline driver's block decomposition exactly:
global decision indices are a running ``arange``, full blocks carry an
all-true validity mask, and :meth:`DecisionService.flush` edge-pads the
ragged tail with the last task's row (``np.pad(mode="edge")``
semantics).

Cache snapshots are double-buffered per §3.2: each block boundary
publishes the post-push cached view into the non-live host buffer and
flips the pointer, so :meth:`DecisionService.snapshot` readers always
see a complete snapshot while the next block writes the other one.
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..sim.cluster import ClusterSpec
from ..sim.engine import (Dynamics, EngineConfig, SimResult, _Carry,
                          _cluster_arrays, _init_carry, _lower_dynamics,
                          _make_block_step, _make_dyn, _make_dyn_ints,
                          _static_cfg, _validate_config, resolve_use_kernel)
from .latency import LatencyRecorder
from .ring import ArrivalRing, ArrivalRows

#: Host-side carry field order for checkpoints (must match _Carry).
_CARRY_FIELDS = _Carry._fields


@partial(jax.jit, donate_argnums=(0,),
         static_argnames=("cfg", "n", "use_kernel", "kernel_masked",
                          "cache_faulted"))
def _serve_step(carry, blk, C, node_type, mem_unit, cores_per, dyn_vec,
                dyn_ints, win, base_key, cfg: EngineConfig, n: int,
                use_kernel: bool, kernel_masked: bool,
                cache_faulted: bool):
    """One decision block through the scan body, with the carry donated.

    Shared across service instances (one compile per static
    configuration); operands are traced arguments exactly as in
    ``_simulate_batched_jax``, so the one-block jaxpr is identical to
    the offline scan body's."""
    step = _make_block_step(C, node_type, mem_unit, cores_per, dyn_vec,
                            dyn_ints, win, base_key, cfg, n, use_kernel,
                            kernel_masked, cache_faulted, False)
    return step(carry, blk)


class DecisionService:
    """Online scheduling over the offline engine's exact arithmetic.

    Usage::

        svc = DecisionService(cluster, EngineConfig(policy="dodoor", b=50))
        svc.submit_workload(wl)          # or submit(...) per chunk
        svc.drain()                      # run every full decision block
        svc.flush()                      # edge-padded ragged tail
        res = svc.result()               # SimResult, bit-exact vs offline

    Supported knobs mirror ``simulate(mode="batched")`` for independent
    tasks: all five policies, ``dynamics`` timelines including
    ``cache_faults``, ``use_kernel``.  ``cfg.retry``, ``cfg.trace``,
    ``cfg.locality`` and DAG workloads run host-side wave loops around
    the scan and are not streamable — they raise ``NotImplementedError``.
    """

    def __init__(self, cluster: ClusterSpec, cfg: EngineConfig, *,
                 seed: int = 0, dynamics=None,
                 use_kernel: bool | str = "auto",
                 capacity: int = 1 << 16,
                 publish_snapshots: bool = True):
        _validate_config(cfg)
        if cfg.retry is not None:
            raise NotImplementedError(
                "DecisionService with a RetryPolicy: the re-entry queue "
                "is a host-side wave loop over the whole stream — run "
                "retries offline via simulate().")
        if cfg.trace:
            raise NotImplementedError(
                "DecisionService with cfg.trace: the decision-trace "
                "ground truth is an offline post-pass — trace via "
                "simulate(mode='batched').")
        if cfg.locality is not None:
            raise NotImplementedError(
                "DecisionService with a LocalityModel: the locality "
                "gather needs parent placements, which only the offline "
                "DAG frontier loop carries.")
        if cfg.outage_ms:
            raise ValueError(
                "EngineConfig.outage_ms is deprecated — pass "
                "Dynamics(store_outages=...) as dynamics.")
        if dynamics is not None and not isinstance(dynamics, Dynamics):
            raise TypeError(f"dynamics must be a Dynamics spec, got "
                            f"{type(dynamics).__name__}")
        use_kernel = resolve_use_kernel(use_kernel, cfg.interpret)
        faulted = dynamics is not None and dynamics.cache_faults is not None
        if faulted:
            use_kernel = False    # megakernel reads only the shared view
        masked = (use_kernel and dynamics is not None
                  and dynamics.has_down_windows)

        n = cluster.num_servers
        self.cluster = cluster
        self.cfg = cfg
        self._n = n
        self._b = cfg.b
        self._seed = int(seed)
        self._use_kernel = use_kernel
        self._masked = masked
        self._faulted = faulted
        self._scfg = _static_cfg(cfg, for_kernel=use_kernel, keep_b=True)
        self._C, self._node_type, self._cores_per, self._mem_unit = \
            _cluster_arrays(cluster, cfg.mem_units)
        self._dyn = _make_dyn(cfg)
        self._dyn_ints = _make_dyn_ints(cfg)
        self._win = _lower_dynamics(dynamics, n)
        self._base_key = jax.random.PRNGKey(self._seed)
        self._carry = _init_carry(self._scfg, n, self._cores_per, faulted)

        self._ring = ArrivalRing(capacity, cluster.num_types)
        self._next_idx = 0
        self._ring_pad = 0    # pad decisions consumed by flush() tails
        self._steps = 0
        self._outs: list[list[np.ndarray]] = [[] for _ in range(8)]
        self.decision_latency = LatencyRecorder()
        self.step_wall = LatencyRecorder()
        self._publish = publish_snapshots
        self._snaps: list[dict | None] = [None, None]
        self._live = -1           # index of the published snapshot buffer

    # -- ingestion --------------------------------------------------------

    @property
    def available(self) -> int:
        """Buffered (submitted, not yet scheduled) tasks."""
        return self._ring.count

    @property
    def scheduled(self) -> int:
        """Decisions made so far (valid tasks through step/flush)."""
        return self._next_idx - self._ring_pad

    @property
    def compiles(self) -> int:
        """Compiled-program count of the shared step — steady-state
        steps must not grow this (asserted in tests)."""
        return _serve_step._cache_size()

    def submit(self, r_submit, r_exec, d_est, d_act, submit_ms) -> int:
        """Enqueue an arrival chunk (numpy planes, any length ≥ 0).
        Records one host enqueue timestamp for the chunk — the start of
        each task's enqueue→placement latency."""
        return self._ring.push(r_submit, r_exec, d_est, d_act, submit_ms,
                               time.perf_counter())

    def submit_workload(self, workload, start: int = 0,
                        stop: int | None = None) -> int:
        """Enqueue a slice of a workload trace (``FBWorkload``-shaped:
        r_submit/r_exec/d_est/d_act/submit_ms)."""
        sl = slice(start, stop)
        return self.submit(workload.r_submit[sl], workload.r_exec[sl],
                           workload.d_est[sl], workload.d_act[sl],
                           workload.submit_ms[sl])

    # -- the step ---------------------------------------------------------

    def step(self) -> int:
        """Run one full decision block (requires ``available ≥ b``).
        Returns the number of tasks placed (= b)."""
        b = self._b
        if self._ring.count < b:
            raise ValueError(
                f"step() needs a full block: {self._ring.count} buffered "
                f"< b={b}; submit more, or flush() the ragged tail")
        rows = self._ring.pop(b)
        return self._run_block(rows, b)

    def drain(self) -> int:
        """Step every full block currently buffered; returns tasks
        placed."""
        done = 0
        while self._ring.count >= self._b:
            done += self.step()
        return done

    def flush(self) -> int:
        """Drain, then run the ragged tail (< b tasks) as one edge-padded
        block — identical to the offline driver's ``np.pad(mode="edge")``
        tail, so placements and ledger stay bit-exact.  Returns tasks
        placed."""
        done = self.drain()
        k = self._ring.count
        if k == 0:
            return done
        rows = self._ring.pop(k)
        pad = self._b - k

        def edge(a):
            return np.concatenate(
                [a, np.repeat(a[-1:], pad, axis=0)], axis=0)

        padded = ArrivalRows(*(edge(np.asarray(p)) for p in rows))
        self._ring_pad += pad
        return done + self._run_block(padded, k)

    def _run_block(self, rows: ArrivalRows, valid_count: int) -> int:
        b = self._b
        t0 = time.perf_counter()
        ids = np.arange(self._next_idx, self._next_idx + b,
                        dtype=np.int32)
        ids_dev = jnp.asarray(ids)
        mask = np.zeros((b,), bool)
        mask[:valid_count] = True
        blk = (ids_dev, jnp.asarray(rows.r_submit),
               jnp.asarray(rows.r_exec), jnp.asarray(rows.d_est),
               jnp.asarray(rows.d_act), jnp.asarray(rows.submit_ms),
               ids_dev, jnp.asarray(mask))
        self._carry, out = _serve_step(
            self._carry, blk, self._C, self._node_type, self._mem_unit,
            self._cores_per, self._dyn, self._dyn_ints, self._win,
            self._base_key, self._scfg, self._n, self._use_kernel,
            self._masked, self._faulted)
        jax.block_until_ready(out)
        t1 = time.perf_counter()
        self.step_wall.record((t1 - t0) * 1e3)
        self.decision_latency.record(
            (t1 - rows.t_enq[:valid_count]) * 1e3)
        for acc, plane in zip(self._outs[:7], out):
            acc.append(np.asarray(plane)[:valid_count])
        self._outs[7].append(rows.submit_ms[:valid_count])
        self._next_idx += b
        self._steps += 1
        if self._publish:
            idx = self._steps % 2
            self._snaps[idx] = {
                "step": self._steps,
                "virtual_ms": float(rows.submit_ms[valid_count - 1]),
                "view_L": np.asarray(self._carry.view_L),
                "view_D": np.asarray(self._carry.view_D),
                "view_rif": np.asarray(self._carry.view_rif),
            }
            self._live = idx
        return valid_count

    # -- results ----------------------------------------------------------

    def snapshot(self) -> dict | None:
        """The most recently *published* cache snapshot (double-buffered:
        never the one the in-flight block is writing), or ``None`` before
        the first step."""
        return self._snaps[self._live] if self._live >= 0 else None

    def result(self) -> SimResult:
        """Everything scheduled so far as a :class:`SimResult` —
        bit-exact vs ``simulate(mode="batched")`` over the same stream.
        Requires an empty ring (``flush()`` first)."""
        if self._ring.count:
            raise ValueError(
                f"{self._ring.count} buffered arrivals not yet scheduled "
                f"— flush() before result()")
        if not self._outs[0]:
            raise ValueError("no decisions yet")
        j, start, finish, enq, sched_ms, cores, mem_mb, submit = (
            np.concatenate(acc) for acc in self._outs)
        msgs = np.asarray(self._carry.msgs)
        return SimResult(
            server=j.astype(np.int32), submit_ms=submit,
            enqueue_ms=enq, start_ms=start, finish_ms=finish,
            sched_ms=sched_ms, cores=cores, mem_mb=mem_mb,
            msgs_base=int(msgs[0]), msgs_probe=int(msgs[1]),
            msgs_push=int(msgs[2]), msgs_flush=int(msgs[3]),
            policy=self.cfg.policy)

    def latency_summary(self) -> dict:
        """Histograms + percentiles for both instrumented clocks."""
        return {
            "decision": {**self.decision_latency.summary(),
                         "histogram": self.decision_latency.histogram()},
            "step": {**self.step_wall.summary(),
                     "histogram": self.step_wall.histogram()},
        }

    # -- checkpoint / resume ----------------------------------------------

    def export_checkpoint(self) -> dict:
        """Snapshot the full scheduling state at a block boundary.  The
        ring must be empty (buffered arrivals belong to the client — they
        are not part of cluster state); resuming a fresh service from the
        returned dict and replaying the remaining stream is bit-exact
        with never having stopped."""
        if self._ring.count:
            raise ValueError(
                f"{self._ring.count} buffered arrivals — drain()/flush() "
                f"before checkpointing (the ring is client state)")
        carry = {f: (None if leaf is None else np.asarray(leaf))
                 for f, leaf in zip(_CARRY_FIELDS, self._carry)}
        return {"carry": carry, "next_idx": int(self._next_idx),
                "ring_pad": int(self._ring_pad), "steps": int(self._steps),
                "seed": self._seed, "policy": self.cfg.policy,
                "b": self._b, "faulted": self._faulted}

    @classmethod
    def from_checkpoint(cls, cluster: ClusterSpec, cfg: EngineConfig,
                        ckpt: dict, **kwargs) -> "DecisionService":
        """Rebuild a service mid-stream from :meth:`export_checkpoint`.
        ``cluster``/``cfg``/``seed``/``dynamics`` must match the
        exporting service (the checkpoint pins the identity-shaping
        ones)."""
        svc = cls(cluster, cfg, seed=ckpt["seed"], **kwargs)
        for key, have in (("policy", cfg.policy), ("b", cfg.b),
                          ("faulted", svc._faulted)):
            if ckpt[key] != have:
                raise ValueError(
                    f"checkpoint {key}={ckpt[key]!r} does not match the "
                    f"restoring service's {have!r}")
        svc._carry = _Carry(**{
            f: (None if v is None else jnp.asarray(v))
            for f, v in ckpt["carry"].items()})
        svc._next_idx = int(ckpt["next_idx"])
        svc._ring_pad = int(ckpt["ring_pad"])
        svc._steps = int(ckpt["steps"])
        return svc


def serve_workload(workload, cluster: ClusterSpec, cfg: EngineConfig, *,
                   seed: int = 0, dynamics=None,
                   use_kernel: bool | str = "auto",
                   chunk: int | None = None, open_loop: bool = False,
                   publish_snapshots: bool = True):
    """Stream a whole workload trace through a fresh service and return
    ``(service, SimResult)``.

    ``open_loop`` submits every chunk up front and then drains (queueing
    pressure: later tasks wait on earlier blocks — tail latency grows);
    the default closed loop alternates submit/step so each block is
    scheduled as soon as it forms.  ``chunk`` is the submission chunk
    size (default ``cfg.b``).  Placements are independent of both knobs
    — only the measured latencies differ."""
    m = workload.r_submit.shape[0]
    chunk = chunk or cfg.b
    svc = DecisionService(cluster, cfg, seed=seed, dynamics=dynamics,
                          use_kernel=use_kernel,
                          capacity=max(m, cfg.b),
                          publish_snapshots=publish_snapshots)
    for lo in range(0, m, chunk):
        svc.submit_workload(workload, lo, min(lo + chunk, m))
        if not open_loop:
            svc.drain()
    svc.flush()
    return svc, svc.result()
