"""Replica pools and request traces as engine inputs.

The serving problem maps onto the §6 simulation engine exactly: replicas
are servers (bins), requests are tasks (balls), decode slots are "cores",
KV HBM is "memory", and the per-type duration vector comes from the request
cost model. This reuse means every scheduling policy, the b-batched data
store, the message accounting and the latency model are shared — Dodoor as
a serving router is the same validated code path as the paper reproduction.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..configs.base import ModelConfig
from ..sim.cluster import ClusterSpec
from .costs import REPLICA_TYPES, request_cost


def make_replica_pool(types=REPLICA_TYPES, interleave: bool = True
                      ) -> ClusterSpec:
    """ClusterSpec over replicas: C = [decode slots, KV-HBM MB]."""
    rows, tids = [], []
    for i, t in enumerate(types):
        for _ in range(t.count):
            rows.append((t.slots, t.hbm_bytes / 1e6))
            tids.append(i)
    C = np.asarray(rows, np.float32)
    tid = np.asarray(tids, np.int32)
    if interleave:
        rng = np.random.RandomState(0)
        perm = rng.permutation(len(tids))
        C, tid = C[perm], tid[perm]
    return ClusterSpec(C=C, node_type=tid,
                       type_names=tuple(t.name for t in types))


@dataclass(frozen=True)
class RequestTrace:
    r_submit: np.ndarray     # [m, 2]
    r_exec: np.ndarray       # [m, T, 2]
    d_est: np.ndarray        # [m, T]
    d_act: np.ndarray        # [m, T]
    task_type: np.ndarray    # [m] bucket id (for reporting)
    submit_ms: np.ndarray    # [m]
    prompt_len: np.ndarray   # [m]
    gen_len: np.ndarray      # [m]


# (prompt, gen) buckets — chat / RAG / summarize / code-complete mixtures.
_BUCKETS = ((256, 128), (1024, 256), (4096, 256), (8192, 128),
            (512, 1024), (2048, 64))


def synthesize_requests(cfg: ModelConfig, m: int, qps: float, *,
                        types=REPLICA_TYPES, seed: int = 0,
                        noise: float = 0.25) -> RequestTrace:
    rng = np.random.RandomState(seed)
    bucket = rng.randint(0, len(_BUCKETS), size=m)
    plen = np.array([_BUCKETS[b][0] for b in bucket], np.int32)
    glen = np.array([_BUCKETS[b][1] for b in bucket], np.int32)
    plen = (plen * np.exp(rng.normal(0, 0.3, m))).astype(np.int32) + 16
    glen = (glen * np.exp(rng.normal(0, 0.3, m))).astype(np.int32) + 4

    T = len(types)
    r = np.zeros((m, 2), np.float32)
    d = np.zeros((m, T), np.float32)
    for i in range(m):
        r[i], d[i] = request_cost(cfg, int(plen[i]), int(glen[i]), types)
    d_act = d * np.exp(rng.normal(0, noise, size=(m, 1))).astype(np.float32)
    submit = np.cumsum(rng.exponential(1000.0 / qps, size=m)
                       ).astype(np.float32)
    return RequestTrace(
        r_submit=r, r_exec=np.repeat(r[:, None, :], T, axis=1),
        d_est=d, d_act=d_act.astype(np.float32),
        task_type=bucket.astype(np.int32), submit_ms=submit,
        prompt_len=plen, gen_len=glen)
