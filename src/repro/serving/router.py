"""Online Dodoor request router — the gateway-side API.

Stateful wrapper around the core Algorithm-1 policy for a live serving
gateway: keeps the scheduler-local cached view, accumulates addNewLoad
deltas, and applies data-store pushes. The fleet-wide simulation
(pool.py + sim.engine) validates the policy; this class is what a real
frontend calls per request.

Failure behaviour inherits the paper's §4.3 soft-pin-out: a dead replica
stops sending overrides, its cached load only rises with new placements,
and the two-choice rule routes around it without any health-check protocol.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import DodoorParams, SchedulerView, dodoor_select, task_key
from ..sim.cluster import ClusterSpec
from .costs import request_cost


@dataclass
class DodoorRouter:
    pool: ClusterSpec
    alpha: float = 0.5
    b: Optional[int] = None            # default n/2 (§3.2)
    seed: int = 0

    def __post_init__(self):
        n = self.pool.num_servers
        self.b = self.b or max(1, n // 2)
        self._params = DodoorParams(alpha=self.alpha, b=self.b)
        self._key = jax.random.PRNGKey(self.seed)
        self._C = jnp.asarray(self.pool.C)
        # scheduler-local cached view (stale by ≤ b decisions)
        self._view_L = np.zeros((n, 2), np.float32)
        self._view_D = np.zeros((n,), np.float32)
        # data-store accumulators
        self._store_L = np.zeros((n, 2), np.float32)
        self._store_D = np.zeros((n,), np.float32)
        self._p = 0
        self._req = 0

    # -- scheduling hot path (no store read, §4.1) -------------------------
    def place(self, cfg, prompt_len: int, gen_len: int) -> int:
        r, d = request_cost(cfg, prompt_len, gen_len,
                            types=self._types())
        d_full = d[self.pool.node_type]
        view = SchedulerView(L=jnp.asarray(self._view_L),
                             D=jnp.asarray(self._view_D),
                             rif=jnp.zeros((self.pool.num_servers,)),
                             C=self._C)
        j = int(dodoor_select(task_key(self._key, self._req),
                              jnp.asarray(r), jnp.asarray(d_full), view,
                              self._params))
        self._req += 1
        # addNewLoad delta (scheduler-side, §4.1)
        self._store_L[j] += r
        self._store_D[j] += d_full[j]
        self._p += 1
        if self._p >= self.b:                    # batch boundary → push
            self._view_L = self._store_L.copy()
            self._view_D = self._store_D.copy()
            self._p = 0
        return j

    # -- server-side override (on request completion) ----------------------
    def complete(self, j: int, r: np.ndarray, d_ms: float):
        self._store_L[j] = np.maximum(0.0, self._store_L[j] - r)
        self._store_D[j] = max(0.0, self._store_D[j] - d_ms)

    def _types(self):
        from .costs import REPLICA_TYPES
        return REPLICA_TYPES
