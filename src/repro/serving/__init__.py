from .costs import ReplicaType, REPLICA_TYPES, request_cost
from .pool import make_replica_pool, synthesize_requests
from .router import DodoorRouter

__all__ = ["ReplicaType", "REPLICA_TYPES", "request_cost",
           "make_replica_pool", "synthesize_requests", "DodoorRouter"]
