"""Per-architecture request cost model for the serving router.

A request (prompt_len, gen_len) against a model replica costs:

* prefill: 2·N_active·prompt_len flops (compute-bound);
* decode:  gen_len steps, each bounded by reading the active weights + the
  KV/state bytes (memory-bound) — the classic serving roofline;
* KV/state residency: bytes held for the request's lifetime.

Replica types model heterogeneous accelerator fleets (the serving analogue
of Table 2's four node types): different peak flops, HBM bandwidth and
capacity. ``request_cost`` returns the per-type duration vector d_ij and the
resource vector r_i = [decode slots, KV bytes] — exactly the inputs of
Algorithm 1.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..configs.base import ModelConfig

BF16 = 2


@dataclass(frozen=True)
class ReplicaType:
    name: str
    peak_flops: float          # effective, per replica
    hbm_bw: float              # bytes/s
    hbm_bytes: float           # capacity for KV after weights
    slots: int                 # concurrent decode lanes
    count: int = 1


# A heterogeneous 4-type fleet (mirrors the paper's testbed diversity):
# flagship / previous-gen / bandwidth-poor / small accelerators.
REPLICA_TYPES = (
    ReplicaType("v5p-like", 459e12, 2765e9, 60e9, slots=16, count=4),
    ReplicaType("v5e-like", 197e12, 819e9, 12e9, slots=8, count=10),
    ReplicaType("v4-like", 275e12, 1228e9, 24e9, slots=8, count=6),
    ReplicaType("edge-like", 90e12, 400e9, 8e9, slots=4, count=12),
)


def kv_bytes_per_token(cfg: ModelConfig) -> float:
    if cfg.family == "ssm":
        return 0.0                         # constant state, not per-token
    if cfg.family == "hybrid":
        pat = cfg._layer_kinds()
        n_attn = sum(1 for k in pat if k == "attn")
        return n_attn * cfg.n_kv * (cfg.head_dim or 0) * 2 * BF16
    return cfg.n_layers * cfg.n_kv * (cfg.head_dim or 0) * 2 * BF16


def state_bytes(cfg: ModelConfig) -> float:
    """Per-sequence constant state (SSM/hybrid)."""
    if cfg.family == "ssm":
        d_in = cfg.ssm_expand * cfg.d_model
        H = d_in // cfg.ssm_headdim
        return cfg.n_layers * H * cfg.ssm_state * cfg.ssm_headdim * 4
    if cfg.family == "hybrid":
        pat = cfg._layer_kinds()
        n_rec = sum(1 for k in pat if k != "attn")
        return n_rec * (cfg.lru_width or cfg.d_model) * 4
    return 0.0


def request_cost(cfg: ModelConfig, prompt_len: int, gen_len: int,
                 types=REPLICA_TYPES):
    """→ (r [2] = [slots, kv_mb], d [T] ms per replica type)."""
    n_act = cfg.active_param_count()
    kv_tok = kv_bytes_per_token(cfg)
    kv_total = kv_tok * (prompt_len + gen_len) + state_bytes(cfg)
    weights = n_act * BF16
    d = []
    for t in types:
        prefill_s = 2.0 * n_act * prompt_len / t.peak_flops
        # one decode step reads weights (amortized over slots) + this
        # request's KV; gen_len steps.
        step_s = (weights / t.slots + kv_total / 2) / t.hbm_bw
        d.append((prefill_s + gen_len * step_s) * 1e3)
    r = np.array([1.0, kv_total / 1e6], np.float32)      # [slot, MB]
    return r, np.array(d, np.float32)
